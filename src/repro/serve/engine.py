"""Batched serving engine: prefill + decode with fixed shapes.

Production disciplines baked in:
* fixed batch/sequence shapes — request padding, never reshape/recompile;
* greedy or temperature sampling with a deterministic per-request key;
* optional DPC-KV compression of the prompt cache before decode
  (dense-attention archs only; SSM/hybrid caches are already O(1)).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model
from repro.serve.dpc_kv import DPCKVConfig, compress_kv


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 8
    max_prompt: int = 512
    max_new_tokens: int = 64
    temperature: float = 0.0      # 0 = greedy
    seed: int = 0
    # Optional DPC-KV compression of the prompt cache (dense-attention archs
    # only; SSM/hybrid caches are already O(1)).  The DPC primitives inside
    # run on dpc_kv.exec_spec — one repro.engine.ExecSpec for serving too.
    dpc_kv: DPCKVConfig | None = None


class ServeEngine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        assert model.is_decoder, f"{model.cfg.name} cannot decode"
        self.model = model
        self.params = params
        self.cfg = cfg
        total = cfg.max_prompt + cfg.max_new_tokens
        self.cache = model.init_cache(cfg.batch, total)
        self._prefill = jax.jit(
            lambda p, b, c: model.prefill(p, b, c))
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos))

    def _pad_prompts(self, prompts: list[list[int]]):
        B, Lp = self.cfg.batch, self.cfg.max_prompt
        assert len(prompts) <= B
        toks = np.zeros((B, Lp), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, p in enumerate(prompts):
            p = p[-Lp:]
            toks[i, Lp - len(p):] = p      # left-pad: all rows end at Lp
            lens[i] = len(p)
        return jnp.asarray(toks), jnp.asarray(lens)

    def compress_prompt_cache(self):
        """DPC-KV compression of the prefilled prompt KV cache.

        Requires cfg.dpc_kv and a dense-attention cache (the transformer
        KVCache layout (L, B, S, K, hd)); call after ``generate``/prefill.
        Returns per-layer compressed caches stacked over layers:
        (k_c, v_c, counts) with shapes (L, B, M, K, hd) x2 and (L, B, M, K).
        Every prompt slot participates (prompts are left-padded, so slots
        [0, max_prompt) all hold prefill-computed keys).
        """
        kv_cfg = self.cfg.dpc_kv
        assert kv_cfg is not None, "ServeConfig.dpc_kv not set"
        k = getattr(self.cache, "k", None)
        v = getattr(self.cache, "v", None)
        assert k is not None and k.ndim == 5, \
            f"{self.model.cfg.name}: cache is not a dense-attention KVCache"
        L, B, S, K, hd = k.shape
        length = min(self.cfg.max_prompt, S)
        # fold layers into the batch axis: one compiled program, not L
        k_c, v_c, counts = compress_kv(k.reshape(L * B, S, K, hd),
                                       v.reshape(L * B, S, K, hd),
                                       jnp.int32(length), kv_cfg)
        M = kv_cfg.budget
        return (k_c.reshape(L, B, M, K, hd), v_c.reshape(L, B, M, K, hd),
                counts.reshape(L, B, M, K))

    def generate(self, prompts: list[list[int]]) -> np.ndarray:
        """Greedy/temperature generation; returns (B, max_new_tokens)."""
        toks, _ = self._pad_prompts(prompts)
        logits, self.cache = self._prefill(self.params, {"tokens": toks},
                                           self.cache)
        key = jax.random.PRNGKey(self.cfg.seed)
        out = []
        pos = self.cfg.max_prompt
        tok = self._sample(logits, key)
        for i in range(self.cfg.max_new_tokens):
            out.append(np.asarray(tok))
            logits, self.cache = self._decode(self.params, self.cache, tok,
                                              jnp.int32(pos + i))
            key, sub = jax.random.split(key)
            tok = self._sample(logits, sub)
        return np.concatenate(out, axis=1)

    def _sample(self, logits, key):
        if self.cfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        scaled = logits.astype(jnp.float32) / self.cfg.temperature
        return jax.random.categorical(key, scaled, axis=-1)[:, None] \
                  .astype(jnp.int32)
