"""DPC-KV: density-peaks compression of attention KV caches.

The paper's clustering is the serving-layer feature here: cached keys of each
(sequence, kv-head) are clustered with DPC and the cache is replaced by one
(k, v) pair per cluster — cluster centers are *density peaks* of the key
distribution, so the kept keys are exactly the attention modes; members are
merged into their center (softmax of attention is locally flat around a
dense mode, so merging members of one peak perturbs outputs least).

Head_dim (64-256) is far above DPC's low-dim regime, so keys are first
projected with a fixed random orthonormal matrix to proj_dim dims — the
dimensionality-reduction recipe the paper itself points to (§2.1).  rho and
the dependent structure are computed in the projected space with the exact
O(n^2/blocked) scan (cache slices are <= a few k tokens per head, where the
quadratic scan is faster than grid construction); centers are the top-M
gamma = rho * delta peaks (the decision-graph rule, Def. 5, with the
threshold replaced by a budget — serving wants a fixed compressed size).

Returns fixed-shape compressed caches: (B, M, n_kv, head_dim) + counts, so
the decode step keeps a static schedule (straggler discipline).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis.audit import audit_determinism
from repro.core.dpc_types import density_jitter, with_jitter
from repro.engine.planner import as_plan
from repro.engine.spec import ExecSpec, merge_legacy
from repro.kernels.backend import get_backend
from repro.resilience.sanitize import finite_or


@dataclass(frozen=True)
class DPCKVConfig:
    """DPC-KV compression parameters.

    Execution is one :class:`repro.engine.ExecSpec` on ``exec_spec`` —
    the kernel backend for the rho / denser-NN primitives (None = platform
    default; the per-head d_cut is a traced scalar, which the kernels
    accept as an SMEM threshold), the sweep block, and the layout
    (``"block-sparse"`` is legal on ``worklist_traceable`` backends whose
    jit-built worklists survive the jit+vmap this module runs under; the
    host-built pallas worklists are rejected *here*, at construction).
    The ``backend`` / ``block`` fields are the legacy spellings and fold
    into the spec with a ``DeprecationWarning`` (see ``repro.engine``).
    """

    budget: int = 256          # M: kept (k, v) pairs per head
    d_cut_quantile: float = 0.05   # d_cut = this quantile of pair distances
    proj_dim: int = 4
    exec_spec: ExecSpec | None = None
    block: int | None = None       # deprecated -> ExecSpec.block
    backend: str | None = None     # deprecated -> ExecSpec.backend

    def __post_init__(self):
        if self.budget < 1:
            raise ValueError(f"budget must be >= 1, got {self.budget!r}")
        ex = merge_legacy(self.exec_spec, owner="DPCKVConfig",
                          backend=self.backend, block=self.block)
        object.__setattr__(self, "exec_spec", ex)
        # THE plan-resolved sweep block (not a field: derived, so equal
        # configs still hash/compare equal as jit static args).  The
        # compression itself is traced code and cannot hold the plan's
        # host-worklist context, but its block default is the planner's.
        pl = as_plan(ex)
        object.__setattr__(self, "resolved_block", pl.resolved_block)
        # fail fast on combos that cannot run under this module's jit+vmap:
        # the whole compression is one traced function per head.
        be = pl.backend
        if ex.sparse and not be.worklist_traceable:
            raise ValueError(
                f"DPC-KV runs under jit; layout='block-sparse' on the "
                f"{be.name!r} backend builds host-side worklists, which "
                f"cannot be constructed in traced code — use the 'jnp' "
                f"backend (jit-built worklists) or the dense layout")
        if ex.resolved_precision == "bf16" and not (be.fused_traceable
                                                    and be.mxu_dense):
            raise ValueError(
                f"DPC-KV precision='bf16' needs a backend whose fused "
                f"rho_delta is both jit-safe and MXU-dense; {be.name!r} "
                f"is not (jnp is the f32 reference, the pallas fused "
                f"epilogue is host-orchestrated)")

    def resolved_exec(self) -> ExecSpec:
        return self.exec_spec


def _project(keys, proj_dim: int, seed: int = 0):
    """Fixed random orthonormal projection (S, hd) -> (S, proj_dim)."""
    hd = keys.shape[-1]
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(seed),
                                           (hd, hd), jnp.float32))
    return keys.astype(jnp.float32) @ q[:, :proj_dim]


def _dcut_estimate(pts, quantile: float):
    """d_cut from a sampled pairwise-distance quantile (paper's 1-2% rule)."""
    S = pts.shape[0]
    m = min(S, 256)
    sub = pts[:: max(S // m, 1)][:m]
    d2 = jnp.sum((sub[:, None, :] - sub[None, :, :]) ** 2, -1)
    d = jnp.sqrt(jnp.maximum(d2, 0.0)).reshape(-1)
    return jnp.quantile(d, quantile) + 1e-6


@partial(jax.jit, static_argnames=("cfg",))
@audit_determinism(
    "the member-slot scatter-adds collide by design (every member of a "
    "cluster lands on its center's slot); on the single-device serving "
    "path XLA lowers them to one fixed in-order loop, and the centroids "
    "they produce are approximate summaries by construction — last-bit "
    "accumulation wobble is within the compressor's accepted error",
    ops=("scatter-add",))
def _compress_head(k_head, v_head, valid, cfg: DPCKVConfig):
    """One (S, hd) head -> (M, hd) k/v + member counts.

    valid: (S,) bool — positions actually written.  Padded/invalid rows get
    rho = -inf so they are never centers and never merged.
    """
    S, hd = k_head.shape
    M = cfg.budget
    pts = _project(k_head, cfg.proj_dim)
    # push invalid rows far away so they do not contribute to any density
    pts = jnp.where(valid[:, None], pts, 1e9 + jnp.arange(S)[:, None] * 1e3)
    d_cut = _dcut_estimate(jnp.where(valid[:, None], pts, 0.0),
                           cfg.d_cut_quantile)
    ex = cfg.resolved_exec()
    be = get_backend(ex.backend)
    block = min(cfg.resolved_block, S)
    layout = "block-sparse" if ex.sparse else None
    if be.fused_traceable:
        # fused rho+delta in one backend call (this whole function runs
        # under jit+vmap, so only jit-safe fused paths qualify; the
        # construction-time validation guarantees the layout/precision
        # axes are jit-legal here).  A -inf jitter on invalid rows makes
        # their keys -inf exactly as the two-pass formulation's masking
        # does.
        jit_mask = jnp.where(valid, density_jitter(S), -jnp.inf)
        rho, rho_key, delta, parent = be.rho_delta(
            pts, pts, d_cut, jitter=jit_mask, block=block,
            precision=ex.precision, layout=layout)
        rho = jnp.where(valid, rho, 0.0)
    else:
        rho = be.range_count(pts, pts, d_cut, block=block, layout=layout)
        rho = jnp.where(valid, rho, 0.0)
        rho_key = with_jitter(rho)
        rho_key = jnp.where(valid, rho_key, -jnp.inf)
        delta, parent = be.denser_nn(pts, rho_key, pts, rho_key,
                                     block=block, layout=layout)
    # global peak: delta = inf -> cap at the domain diameter for gamma
    delta = finite_or(delta, 2.0 * d_cut * 10.0)
    gamma = jnp.where(valid, rho * delta, -jnp.inf)

    # top-M gamma peaks are the kept centers
    _, centers = jax.lax.top_k(gamma, M)                     # (M,) indices
    is_center = jnp.zeros((S,), bool).at[centers].set(True) & valid

    # members follow dependent chains to the nearest center (pointer jump)
    import math
    p = jnp.where(is_center | (parent < 0), jnp.arange(S), parent)
    for _ in range(max(int(math.ceil(math.log2(max(S, 2)))), 1)):
        p = jnp.where(is_center[p], p, p[p])
    root = p                                                  # (S,)
    # map each root to its slot in the centers list (or drop)
    slot_of = jnp.full((S,), M, jnp.int32).at[centers].set(
        jnp.arange(M, dtype=jnp.int32))
    member_slot = jnp.where(valid & is_center[root], slot_of[root], M)

    ones = (member_slot < M).astype(jnp.float32)
    counts = jnp.zeros((M + 1,), jnp.float32).at[member_slot].add(ones)[:M]
    ksum = jnp.zeros((M + 1, hd), jnp.float32).at[member_slot].add(
        k_head.astype(jnp.float32) * ones[:, None])[:M]
    vsum = jnp.zeros((M + 1, hd), jnp.float32).at[member_slot].add(
        v_head.astype(jnp.float32) * ones[:, None])[:M]
    denom = jnp.maximum(counts, 1.0)[:, None]
    k_out = (ksum / denom).astype(k_head.dtype)
    v_out = (vsum / denom).astype(v_head.dtype)
    return k_out, v_out, counts


def compress_kv(k, v, length, cfg: DPCKVConfig):
    """k/v: (B, S, n_kv, hd); length: () or (B,) valid prefix length.

    Returns (k_c, v_c, counts): (B, M, n_kv, hd) x2 and (B, M, n_kv).
    ``counts`` feed the attention correction  log(count) added to logits —
    a merged center stands for `count` keys (mass-preserving softmax).
    """
    B, S, K, hd = k.shape
    length = jnp.broadcast_to(jnp.asarray(length), (B,))
    valid = jnp.arange(S)[None, :] < length[:, None]          # (B, S)

    def per_bk(kk, vv, val):
        return _compress_head(kk, vv, val, cfg)

    # outer vmap eats the batch axis, so heads sit at axis 1 of (S, K, hd)
    f = jax.vmap(jax.vmap(per_bk, in_axes=(1, 1, None), out_axes=(1, 1, 1)),
                 in_axes=(0, 0, 0))
    k_c, v_c, counts = f(k, v, valid)
    return k_c, v_c, counts


def attend_compressed(q, k_c, v_c, counts, scale=None):
    """Reference attention over a compressed cache with mass correction.

    q: (B, H, hd); k_c/v_c: (B, M, K, hd); counts: (B, M, K).
    Returns (B, H, hd).  Used by tests/benchmarks to measure the
    output error of DPC-KV against full-cache attention.
    """
    B, H, hd = q.shape
    Kh = k_c.shape[2]
    G = H // Kh
    qg = q.reshape(B, Kh, G, hd).astype(jnp.float32)
    scale = scale if scale is not None else hd ** -0.5
    logits = jnp.einsum("bkgh,bmkh->bkgm", qg, k_c.astype(jnp.float32))
    logits = logits * scale + jnp.log(jnp.maximum(
        counts, 1e-9)).transpose(0, 2, 1)[:, :, None, :]
    logits = jnp.where(counts.transpose(0, 2, 1)[:, :, None, :] > 0,
                       logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgm,bmkh->bkgh", probs, v_c.astype(jnp.float32))
    return out.reshape(B, H, hd)
