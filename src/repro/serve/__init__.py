"""Serving layer: batched engine (prefill + decode) and DPC-KV compression."""
from .engine import ServeConfig, ServeEngine
from .dpc_kv import DPCKVConfig, compress_kv

__all__ = ["ServeConfig", "ServeEngine", "DPCKVConfig", "compress_kv"]
