"""Serving layer: batched engine (prefill + decode), DPC-KV compression,
and the online-clustering endpoint (re-exported from ``repro.stream``)."""
from .engine import ServeConfig, ServeEngine
from .dpc_kv import DPCKVConfig, compress_kv
from repro.stream.service import (QueryResult, QueryStatus,
                                 StreamServeConfig, StreamService)

__all__ = ["ServeConfig", "ServeEngine", "DPCKVConfig", "compress_kv",
           "StreamService", "StreamServeConfig", "QueryResult",
           "QueryStatus"]
