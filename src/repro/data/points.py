"""Synthetic point-set generators mirroring the paper's datasets (§6).

* ``gaussian_mixture`` — S1..S4 analogues: 15 Gaussian clusters in [0,1e5]^2
  with a controllable overlap degree (Franti & Sieranoja's S-sets knob).
* ``random_walk`` — the Syn dataset of [17]: cluster centers from a random
  walk, points scattered around them; 13 density peaks by default.
* ``with_noise`` — uniform background noise at a given rate (Table 2).
* ``real_proxy`` — distribution-matched stand-ins for Airline/Household/
  PAMAP2/Sensor (mixtures with skewed densities at the paper's dims/domains);
  the real files are not redistributable offline (DESIGN.md §9.5).
"""
from __future__ import annotations

import numpy as np

DOMAIN = 1e5


def gaussian_mixture(n: int, k: int = 15, d: int = 2, overlap: float = 0.02,
                     seed: int = 0, domain: float = DOMAIN):
    """k Gaussian blobs; ``overlap`` scales sigma relative to the domain."""
    rng = np.random.default_rng(seed)
    centers = rng.uniform(0.15 * domain, 0.85 * domain, size=(k, d))
    sizes = np.full(k, n // k)
    sizes[: n - sizes.sum()] += 1
    pts = []
    labels = []
    for i, (c, m) in enumerate(zip(centers, sizes)):
        pts.append(rng.normal(c, overlap * domain, size=(m, d)))
        labels.append(np.full(m, i))
    x = np.concatenate(pts).astype(np.float32)
    y = np.concatenate(labels).astype(np.int32)
    p = rng.permutation(n)
    return np.clip(x[p], 0, domain), y[p]


def random_walk(n: int, k: int = 13, d: int = 2, seed: int = 0,
                domain: float = DOMAIN, step: float = 0.18,
                sigma: float = 0.025):
    """Syn-style dataset: cluster centers on a random walk [Gan & Tao '15]."""
    rng = np.random.default_rng(seed)
    centers = [rng.uniform(0.2 * domain, 0.8 * domain, size=d)]
    for _ in range(k - 1):
        nxt = centers[-1] + rng.normal(0, step * domain, size=d)
        centers.append(np.clip(nxt, 0.1 * domain, 0.9 * domain))
    centers = np.stack(centers)
    sizes = rng.multinomial(n, np.ones(k) / k)
    pts, labels = [], []
    for i, (c, m) in enumerate(zip(centers, sizes)):
        pts.append(rng.normal(c, sigma * domain, size=(m, d)))
        labels.append(np.full(m, i))
    x = np.concatenate(pts).astype(np.float32)
    y = np.concatenate(labels).astype(np.int32)
    p = rng.permutation(len(x))
    return np.clip(x[p], 0, domain), y[p]


def with_noise(points: np.ndarray, labels: np.ndarray, rate: float,
               seed: int = 1, domain: float = DOMAIN):
    """Add uniform noise points; noise gets label -1 (Table 2 setup)."""
    rng = np.random.default_rng(seed)
    m = int(len(points) * rate)
    noise = rng.uniform(0, domain, size=(m, points.shape[1])).astype(np.float32)
    x = np.concatenate([points, noise])
    y = np.concatenate([labels, np.full(m, -1, np.int32)])
    p = rng.permutation(len(x))
    return x[p], y[p]


def drifting_batches(batch: int, ticks: int, k: int = 13, d: int = 2,
                     seed: int = 0, domain: float = DOMAIN,
                     step: float = 0.18, sigma: float = 0.025,
                     drift: float = 0.01):
    """Streaming variant of ``random_walk``: yields one micro-batch per tick
    while the cluster centers keep random-walking (``drift`` * domain per
    tick).  Yields ``(points (batch, d), labels (batch,), centers (k, d))``
    — the workload for sliding-window cluster-continuity demos/tests.
    """
    rng = np.random.default_rng(seed)
    centers = [rng.uniform(0.2 * domain, 0.8 * domain, size=d)]
    for _ in range(k - 1):
        nxt = centers[-1] + rng.normal(0, step * domain, size=d)
        centers.append(np.clip(nxt, 0.1 * domain, 0.9 * domain))
    centers = np.stack(centers)
    for _ in range(ticks):
        centers = np.clip(centers + rng.normal(0, drift * domain, centers.shape),
                          0.05 * domain, 0.95 * domain)
        idx = rng.integers(0, k, size=batch)
        pts = centers[idx] + rng.normal(0, sigma * domain, size=(batch, d))
        yield (np.clip(pts, 0, domain).astype(np.float32),
               idx.astype(np.int32), centers.copy())


_REAL_PROXIES = {
    # name: (d, skew, n_clusters) — domains per §6 of the paper
    "airline": (3, 2.5, 24),
    "household": (4, 1.8, 18),
    "pamap2": (4, 2.2, 20),
    "sensor": (8, 1.5, 12),
}


def real_proxy(name: str, n: int, seed: int = 0, domain: float = DOMAIN):
    """Skewed-density mixture matched to the real dataset's dim/cardinality."""
    d, skew, k = _REAL_PROXIES[name]
    rng = np.random.default_rng(seed + hash(name) % 2**16)
    centers = rng.uniform(0.1 * domain, 0.9 * domain, size=(k, d))
    # power-law cluster sizes -> skewed densities (what defeats k-means pivots)
    weights = rng.pareto(skew, k) + 0.05
    weights /= weights.sum()
    sizes = rng.multinomial(n, weights)
    sigmas = rng.uniform(0.005, 0.05, k) * domain
    pts, labels = [], []
    for i in range(k):
        pts.append(rng.normal(centers[i], sigmas[i], size=(sizes[i], d)))
        labels.append(np.full(sizes[i], i))
    x = np.concatenate(pts).astype(np.float32)
    y = np.concatenate(labels).astype(np.int32)
    p = rng.permutation(len(x))
    return np.clip(x[p], 0, domain), y[p]
