"""Deterministic synthetic LM data pipeline with a restorable cursor.

Production properties that matter for the fault-tolerance story:

* fixed-shape batches — a slow/restarted host can never change the
  collective schedule (straggler discipline);
* stateless indexing — batch ``i`` is a pure function of (seed, i), so a
  restore from step N replays exactly the stream the crashed run would have
  produced (the checkpoint stores only the cursor);
* per-family batch dicts matching ``configs.input_specs``.

The token source is a mixture of Zipf-distributed unigram draws and repeated
motif spans, which gives a learnable (compressible) stream — enough signal
for the examples/train_lm.py loss to drop visibly in a few hundred steps.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.models.common import ArchConfig


@dataclass
class TokenPipeline:
    cfg: ArchConfig
    batch: int
    seq_len: int
    seed: int = 0
    cursor: int = 0           # batches already emitted (checkpointed)

    def _rng(self, i: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, i]))

    def _tokens(self, rng, shape) -> np.ndarray:
        V = self.cfg.vocab
        # Zipf unigrams bounded to the vocab
        z = rng.zipf(1.3, size=shape).astype(np.int64)
        toks = (z - 1) % V
        # overwrite random spans with repeated motifs (learnable structure)
        B, L = shape
        motif = rng.integers(0, V, size=16)
        for b in range(B):
            for _ in range(max(1, L // 256)):
                s = int(rng.integers(0, max(L - 16, 1)))
                toks[b, s:s + 16] = motif[: max(0, min(16, L - s))]
        return toks.astype(np.int32)

    def batch_at(self, i: int) -> dict:
        """Batch ``i`` as numpy arrays (pure function of seed and i)."""
        rng = self._rng(i)
        cfg, B, L = self.cfg, self.batch, self.seq_len
        if cfg.family == "encoder":
            return {
                "features": rng.normal(0, 1, (B, L, cfg.frontend_dim))
                              .astype(np.float32),
                "labels": rng.integers(0, cfg.vocab, (B, L)).astype(np.int32),
            }
        if cfg.family == "vlm":
            return {
                "patches": rng.normal(0, 1, (B, cfg.num_patches,
                                             cfg.frontend_dim))
                             .astype(np.float32),
                "tokens": self._tokens(rng, (B, L - cfg.num_patches)),
            }
        return {"tokens": self._tokens(rng, (B, L))}

    def __next__(self) -> dict:
        out = self.batch_at(self.cursor)
        self.cursor += 1
        return out

    def __iter__(self):
        return self

    # ---- checkpoint integration -------------------------------------
    def state_dict(self) -> dict:
        return {"seed": self.seed, "cursor": self.cursor}

    def load_state_dict(self, state: dict) -> None:
        assert int(state["seed"]) == self.seed, \
            "restoring a pipeline with a different data seed"
        self.cursor = int(state["cursor"])
