"""Data pipelines: synthetic point sets (DPC) and token streams (LM)."""
