"""Core DPC algorithms (the paper's contribution) in JAX."""
from .dpc_api import (Clustering, DPCConfig, DPCResult, assign_labels, cluster,
                      compute_dpc, decision_graph)
from .metrics import rand_index
from .tuning import pick_dcut

__all__ = ["DPCConfig", "DPCResult", "Clustering", "compute_dpc", "cluster",
           "assign_labels", "decision_graph", "rand_index", "pick_dcut"]
