"""Approx-DPC (§4): exact rho, O(1) approximate dependents, same centers.

Paper rules, realized with segment ops over the grouping grid G (side
d_cut/sqrt(d), in-cell diameter < d_cut):

1. p_i != p*(cell)  ->  parent = p*(cell), delta = d_cut.     [segment argmax]
2. p_i == p*(cell)  ->  nearest denser point within d_cut via the stencil
   (the paper's N(c)/min-rho test, evaluated directly in vector form);
   if found: parent = it, delta = d_cut.
3. otherwise (cell-max with no denser point within d_cut): exact global
   masked-NN fallback — these are the "stem" roots, |roots| << n.

rho is exact (joint per-cell range count), so Theorem 4 (identical cluster
centers to Ex-DPC for the same rho_min/delta_min) carries over: every point
resolved by rules 1-2 has true delta < d_cut < delta_min under Ex-DPC too, and
every root gets its exact delta.  Property-tested in tests/test_dpc_core.py.

With a pallas backend the grouping grid (rule 1) is unchanged but both hot
primitives come from ONE fused ``rho_delta`` tile sweep (kernels/sweep.py):
the same pass that counts every row's density also keeps its k nearest
candidates, so rules 2 and 3 read the per-row denser-NN for the cell maxima
with no second table sweep — the NN is within d_cut iff rule 2 fires, and
otherwise IS the rule-3 exact root distance.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.engine.planner import as_plan

from .dpc_types import DPCResult, density_jitter, with_jitter
from .exdpc import resolve_fallback
from .grid import build_grid, Grid, unsort_dpc
from .stencil import density_per_cell, dependent_stencil


def _group_segments(grid: Grid):
    """Contiguous grouping-cell segment id per sorted point (G is a refinement
    of the candidate grid on the leading dims, so one sort serves both)."""
    gk = grid.group_key
    is_first = jnp.concatenate([jnp.ones((1,), bool), gk[1:] != gk[:-1]])
    return (jnp.cumsum(is_first) - 1).astype(jnp.int32)


def run_approxdpc(points, d_cut: float, *, g: int | None = None,
                  cell_block: int = 32, fallback_block: int = 4096,
                  grid: Grid | None = None, exec_spec=None) -> DPCResult:
    points = jnp.asarray(points, jnp.float32)
    pl = as_plan(exec_spec, points)
    n = points.shape[0]
    block = pl.block or 256     # stencil row-tile default (jnp path)
    if grid is None:
        with obs.span("approxdpc.grid", n=n) as sp:
            grid = sp.sync(build_grid(points, d_cut, g=g))

    seg = _group_segments(grid)
    sparse = pl.sparse

    # --- exact local density: joint per-cell range count (§4.2) on the
    #     reference backend, fused rho+delta tile sweep on pallas (or any
    #     backend in the grid-pruned block-sparse layout) ---
    nn_delta_all = nn_parent_all = None
    use_engine = pl.backend.mxu_dense or sparse
    if sparse:
        def _maxima_mask_sorted(rk_s):
            # the engine ran on the grid-sorted table, so the interest
            # mask is directly the per-cell argmax in sorted space
            seg_max = jax.ops.segment_max(rk_s, seg, num_segments=n)
            return rk_s == seg_max[seg]

        with obs.span("approxdpc.rho_delta", n=n, layout=pl.layout) as sp:
            rho_s, rk_s, nnd_s, nnp_s = pl.rho_delta(
                grid.points, grid.points, d_cut,
                jitter=density_jitter(n)[grid.order],
                fallback_interest=_maxima_mask_sorted)
            rho, rho_key, nn_delta_all, nn_parent_all = sp.sync(unsort_dpc(
                grid, rho_s, rk_s, nnd_s, nnp_s))
    elif use_engine:
        def _maxima_mask(rho_key):
            # only cell maxima consume the Def.-2 answer (rules 2+3), so the
            # fused path's unresolved-row fallback is restricted to them —
            # the |G| << n rectangular pass the paper's cost model counts on
            rk_s = rho_key[grid.order]
            seg_max = jax.ops.segment_max(rk_s, seg, num_segments=n)
            return (rk_s == seg_max[seg])[grid.inv_order]

        # one engine invocation answers Def. 1 for every row AND Def. 2 for
        # the rows that will need it (the cell maxima, picked below)
        with obs.span("approxdpc.rho_delta", n=n, layout=pl.layout) as sp:
            rho, rho_key, nn_delta_all, nn_parent_all = sp.sync(pl.rho_delta(
                points, points, d_cut, jitter=density_jitter(n),
                fallback_interest=_maxima_mask))
    else:
        with obs.span("approxdpc.rho", n=n) as sp:
            rho = sp.sync(density_per_cell(grid,
                                           block=cell_block)[grid.inv_order])
        rho_key = with_jitter(rho)
    rk_sorted = rho_key[grid.order]

    # --- rule 1: in-cell O(1) dependents via segment argmax ---
    num_seg = n  # <= n segments; segment ops padded to n
    seg_max = jax.ops.segment_max(rk_sorted, seg, num_segments=num_seg)
    is_cellmax = rk_sorted == seg_max[seg]
    # index of each cell's max point (sorted order)
    slot = jnp.arange(n, dtype=jnp.int32)
    cellmax_slot = jax.ops.segment_max(jnp.where(is_cellmax, slot, -1), seg,
                                       num_segments=num_seg)
    parent_s = cellmax_slot[seg]                 # rule-1 parent (sorted idx)
    delta_s = jnp.full((n,), grid.d_cut, jnp.float32)

    if use_engine:
        # --- rules 2+3 from the fused sweep's per-row denser-NN: only the
        #     cell maxima consume it (every other row is rule 1).  NN within
        #     d_cut -> rule 2 (delta stamped d_cut); NN beyond d_cut ->
        #     rule 3 exact root delta (inf at the peak).
        with obs.span("approxdpc.rules", n=n) as sp:
            is_cm = np.asarray(is_cellmax[grid.inv_order])
            cm_rows = is_cm.nonzero()[0]
            nn_delta = nn_delta_all[cm_rows]
            nn_parent = nn_parent_all[cm_rows]
            parent1 = jnp.where(parent_s >= 0, grid.order[parent_s], -1)
            parent1 = parent1[grid.inv_order]
            found2 = jnp.isfinite(nn_delta) & (nn_delta < d_cut)
            cm_delta = jnp.where(found2, jnp.float32(d_cut),
                                 jnp.where(jnp.isfinite(nn_delta), nn_delta,
                                           jnp.inf))
            delta = jnp.full((n,), d_cut,
                             jnp.float32).at[cm_rows].set(cm_delta)
            parent = parent1.at[cm_rows].set(nn_parent).astype(jnp.int32)
            sp.sync((delta, parent))
        return DPCResult(rho=rho, rho_key=rho_key, delta=delta,
                         parent=parent)

    resolved_s = ~is_cellmax

    # --- rule 2: cell maxima consult the d_cut stencil ---
    # (the stencil pass computes for every point; only cell maxima consume it.
    #  This is the vector-SPMD trade: lanes are cheaper than gather plumbing.)
    with obs.span("approxdpc.stencil", n=n) as sp:
        st_delta, st_parent, st_found = dependent_stencil(grid, rk_sorted,
                                                          block=block)
        use2 = is_cellmax & st_found
        parent_s = jnp.where(use2, st_parent, parent_s)
        delta_s = jnp.where(use2, jnp.float32(grid.d_cut), delta_s)  # paper: d_cut
        resolved_s = resolved_s | use2

        delta = delta_s[grid.inv_order]
        parent_sorted = parent_s[grid.inv_order]
        parent = jnp.where(parent_sorted >= 0, grid.order[parent_sorted],
                           -1).astype(jnp.int32)
        resolved = sp.sync(resolved_s[grid.inv_order])

    # --- rule 3: exact fallback for the stem roots ---
    with obs.span("approxdpc.fallback") as sp:
        delta, parent = sp.sync(resolve_fallback(
            points, rho_key, delta, parent, resolved,
            block=fallback_block, backend=pl.backend))
    return DPCResult(rho=rho, rho_key=rho_key, delta=delta,
                     parent=parent.astype(jnp.int32))
