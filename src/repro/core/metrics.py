"""Clustering quality metrics: Rand index (paper Tables 2-5)."""
from __future__ import annotations

import numpy as np


def rand_index(labels_a, labels_b) -> float:
    """Rand index between two labelings; noise (-1) is treated as a label.

    Computed from the contingency table: RI = 1 - (A + B - 2*AB) / C(n,2) where
    A/B are same-pair counts of each labeling and AB of the intersection.
    """
    a = np.asarray(labels_a).astype(np.int64)
    b = np.asarray(labels_b).astype(np.int64)
    assert a.shape == b.shape
    n = a.shape[0]
    if n < 2:
        return 1.0
    _, a = np.unique(a, return_inverse=True)
    _, b = np.unique(b, return_inverse=True)
    ka, kb = a.max() + 1, b.max() + 1
    cont = np.zeros((ka, kb), dtype=np.int64)
    np.add.at(cont, (a, b), 1)

    def comb2(x):
        return (x * (x - 1)) // 2

    sum_ab = comb2(cont).sum()
    sum_a = comb2(cont.sum(axis=1)).sum()
    sum_b = comb2(cont.sum(axis=0)).sum()
    total = comb2(np.int64(n))
    return float((total + 2 * sum_ab - sum_a - sum_b) / total)
