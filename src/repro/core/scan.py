"""Scan: the paper's straightforward O(n^2) DPC (§2.1). Correctness oracle.

Row x column blocked so memory stays O(block^2).  The default (``jnp``)
backend uses the direct difference form of squared distance — bit-identical
to the grid/stencil path, so exact algorithms can be compared with equality,
not tolerances.  With a pallas backend the same two primitives run as MXU
expanded-form tiles (threshold-safe tolerances apply — see
tests/test_kernels.py); ``run_scan`` is then the dense-hardware DPC rather
than the oracle.

Since the unified tile-sweep engine landed, ``run_scan`` drives the fused
``rho_delta`` primitive — Def. 1 and Def. 2 answered by one backend call
(one shared jit on ``jnp``, one kernel sweep + direct-diff epilogue on
pallas) instead of two back-to-back table sweeps.  The fused path is
bit-parity-tested against the sequential formulation per backend
(tests/test_sweep_fused.py), so the oracle contract is unchanged.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro import obs
from repro.engine.planner import as_plan
from repro.kernels.backend import get_backend

from .dpc_types import DPCResult, density_jitter
from .grid import build_grid, unsort_dpc


def local_density_scan(points: jnp.ndarray, d_cut: float,
                       block: int = 512) -> jnp.ndarray:
    """rho_i = |{j : dist(i,j) < d_cut}| by blocked full scan (self included).

    Thin alias of the jnp backend's range-count primitive — one point of
    truth for the direct-difference math the oracle contract relies on.
    """
    return get_backend("jnp").range_count(points, points, d_cut, block=block)


def dependent_scan(points: jnp.ndarray, rho_key: jnp.ndarray,
                   block: int = 512):
    """Exact dependent point/distance by blocked full scan with a rho mask
    (alias of the jnp backend's denser-NN primitive, see above)."""
    return get_backend("jnp").denser_nn(points, rho_key, points, rho_key,
                                        block=block)


def run_scan(points, d_cut: float, *, exec_spec=None) -> DPCResult:
    """O(n^2) DPC through the planned kernel backend (``exec_spec``: an
    :class:`repro.engine.ExecSpec` or prepared :class:`~repro.engine.DPCPlan`;
    ``None`` -> platform default, the bit-exact ``jnp`` oracle on CPU).

    ``ExecSpec(layout="block-sparse")`` grid-sorts the points and runs the
    fused primitive in the grid-pruned worklist mode — sub-quadratic tile
    work under the paper's d_cut assumption, same outputs (Scan then is no
    longer "the straightforward algorithm", but it is the same function).
    """
    points = jnp.asarray(points, jnp.float32)
    pl = as_plan(exec_spec, points)
    n = points.shape[0]
    if pl.grid_sort:
        with obs.span("scan.grid", n=n) as sp:
            grid = sp.sync(build_grid(points, d_cut))
        with obs.span("scan.rho_delta", n=n, layout=pl.layout) as sp:
            rho_s, rk_s, dd_s, pp_s = pl.rho_delta(
                grid.points, grid.points, d_cut,
                jitter=density_jitter(n)[grid.order])
            rho, rho_key, delta, parent = sp.sync(
                unsort_dpc(grid, rho_s, rk_s, dd_s, pp_s))
        return DPCResult(rho=rho, rho_key=rho_key, delta=delta,
                         parent=parent)
    with obs.span("scan.rho_delta", n=n, layout=pl.layout) as sp:
        rho, rho_key, delta, parent = sp.sync(pl.rho_delta(
            points, points, d_cut, jitter=density_jitter(n)))
    return DPCResult(rho=rho, rho_key=rho_key, delta=delta,
                     parent=parent.astype(jnp.int32))
