"""Scan: the paper's straightforward O(n^2) DPC (§2.1). Correctness oracle.

Row x column blocked so memory stays O(block^2).  Uses the direct
difference form of squared distance — bit-identical to the grid/stencil path,
so exact algorithms can be compared with equality, not tolerances.  (The
Pallas kernels use the MXU expanded form; their tests use threshold-safe
tolerances instead — see tests/test_kernels.py.)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .dpc_types import DPCResult, with_jitter


@partial(jax.jit, static_argnames=("block",))
def local_density_scan(points: jnp.ndarray, d_cut: float, block: int = 512) -> jnp.ndarray:
    """rho_i = |{j : dist(i,j) < d_cut}| by blocked full scan (self included)."""
    n, d = points.shape
    nb = -(-n // block)
    npad = nb * block
    pts = jnp.pad(points, ((0, npad - n), (0, 0)), constant_values=jnp.inf)
    d2cut = jnp.float32(d_cut) ** 2

    def row_block(i0):
        rows = jax.lax.dynamic_slice_in_dim(pts, i0, block, 0)

        def col_block(j, acc):
            cols = jax.lax.dynamic_slice_in_dim(pts, j * block, block, 0)
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            return acc + jnp.sum(d2 < d2cut, axis=1).astype(jnp.int32)

        return jax.lax.fori_loop(0, nb, col_block, jnp.zeros((block,), jnp.int32))

    cnt = jax.lax.map(row_block, jnp.arange(nb) * block).reshape(-1)[:n]
    return cnt.astype(jnp.float32)


@partial(jax.jit, static_argnames=("block",))
def dependent_scan(points: jnp.ndarray, rho_key: jnp.ndarray, block: int = 512):
    """Exact dependent point/distance by blocked full scan with a rho mask."""
    n, d = points.shape
    nb = -(-n // block)
    npad = nb * block
    pts = jnp.pad(points, ((0, npad - n), (0, 0)), constant_values=jnp.inf)
    rk = jnp.pad(rho_key, (0, npad - n), constant_values=-jnp.inf)

    def row_block(i0):
        rows = jax.lax.dynamic_slice_in_dim(pts, i0, block, 0)
        rrk = jax.lax.dynamic_slice_in_dim(rk, i0, block, 0)

        def col_block(j, carry):
            best, arg = carry
            cols = jax.lax.dynamic_slice_in_dim(pts, j * block, block, 0)
            crk = jax.lax.dynamic_slice_in_dim(rk, j * block, block, 0)
            d2 = jnp.sum((rows[:, None, :] - cols[None, :, :]) ** 2, -1)
            d2 = jnp.where(crk[None, :] > rrk[:, None], d2, jnp.inf)
            jj = jnp.argmin(d2, axis=1)
            cand = d2[jnp.arange(block), jj]
            better = cand < best
            return (jnp.where(better, cand, best),
                    jnp.where(better, j * block + jj, arg))

        best, arg = jax.lax.fori_loop(
            0, nb, col_block,
            (jnp.full((block,), jnp.inf), jnp.full((block,), -1, jnp.int64)))
        return jnp.sqrt(best), jnp.where(jnp.isfinite(best), arg, -1)

    delta, parent = jax.lax.map(row_block, jnp.arange(nb) * block)
    return delta.reshape(-1)[:n], parent.reshape(-1)[:n].astype(jnp.int32)


def run_scan(points, d_cut: float, block: int = 512) -> DPCResult:
    points = jnp.asarray(points, jnp.float32)
    rho = local_density_scan(points, d_cut, block=block)
    rho_key = with_jitter(rho)
    delta, parent = dependent_scan(points, rho_key, block=block)
    return DPCResult(rho=rho, rho_key=rho_key, delta=delta, parent=parent)
