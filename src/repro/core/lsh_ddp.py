"""LSH-DDP baseline [Zhang et al., TKDE'16] — the paper's state-of-the-art
approximate competitor (§2.2, §6).

p-stable compound LSH partitions P into buckets; rho and the dependent point
are approximated *within* the point's bucket; points that find no denser point
in any bucket fall back to a full scan.  M independent rounds refine the
estimates (rho: max over rounds — in-bucket counts only undercount; delta: min
over rounds).  As in the paper, both rho and delta are approximate, which is
exactly why its Rand index trails Approx-DPC (Tables 2-4).
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from .dpc_types import DPCResult, with_jitter
from .exdpc import _pow2_pad
from .stencil import masked_nn_rows


@partial(jax.jit, static_argnames=("L", "cap", "block"))
def _bucket_round(points, key, d_cut, L: int, cap: int, block: int = 64):
    """One compound-LSH partition round: in-bucket rho counts + denser-NN."""
    n, d = points.shape
    w = 2.0 * d_cut
    ka, kb = jax.random.split(key)
    a = jax.random.normal(ka, (d, L), jnp.float32)
    b = jax.random.uniform(kb, (L,), jnp.float32) * w
    h = jnp.floor((points @ a + b) / w).astype(jnp.int64)          # (n, L)
    # mix the L hash values into one bucket id
    bid = jnp.zeros((n,), jnp.int64)
    for l in range(L):
        bid = bid * jnp.int64(1000003) + h[:, l]
    order = jnp.argsort(bid)
    inv = jnp.argsort(order)
    bs = bid[order]
    pts_s = points[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), bs[1:] != bs[:-1]])
    seg = jnp.cumsum(is_first) - 1
    start = jax.ops.segment_min(jnp.where(is_first, jnp.arange(n), n), seg,
                                num_segments=n)[seg]               # (n,)
    d2cut = jnp.float32(d_cut) ** 2
    nb = -(-n // block)
    npad = nb * block
    pts_p = jnp.pad(pts_s, ((0, npad - n), (0, 0)))
    st_p = jnp.pad(start, (0, npad - n), constant_values=n)

    def chunk(i0):
        rows = jax.lax.dynamic_slice_in_dim(pts_p, i0, block, 0)
        st = jax.lax.dynamic_slice_in_dim(st_p, i0, block, 0)
        idx = st[:, None] + jnp.arange(cap)                        # (B, cap)
        valid = (idx < n) & (bs[jnp.minimum(idx, n - 1)] ==
                             bs[jnp.minimum(i0 + jnp.arange(block), n - 1)][:, None])
        cand = pts_s[jnp.minimum(idx, n - 1)]
        d2 = jnp.sum((rows[:, None, :] - cand) ** 2, -1)
        cnt = jnp.sum((d2 < d2cut) & valid, axis=1)
        return cnt, idx, valid, d2

    counts = []
    mins = []
    arg = []
    # two passes: first rho (needs all counts), then denser-NN with rho known
    cnts = jax.lax.map(lambda i0: chunk(i0)[0], jnp.arange(nb) * block)
    rho_s = cnts.reshape(-1)[:n].astype(jnp.float32)
    rho = rho_s[inv]
    return rho, order, inv, bs, pts_s, st_p, npad


def run_lsh_ddp(points, d_cut: float, *, M: int = 4, L: int = 3,
                cap: int | None = None, block: int = 64, seed: int = 0,
                fallback_block: int = 4096) -> DPCResult:
    points = jnp.asarray(points, jnp.float32)
    n, d = points.shape
    key = jax.random.PRNGKey(seed)
    rho_best = jnp.zeros((n,), jnp.float32)
    rounds = []
    for r in range(M):
        key, sub = jax.random.split(key)
        # measure bucket capacity for this round on the host
        w = 2.0 * d_cut
        ka, kb = jax.random.split(sub)
        a = jax.random.normal(ka, (d, L), jnp.float32)
        b = jax.random.uniform(kb, (L,), jnp.float32) * w
        h = jnp.floor((points @ a + b) / w).astype(jnp.int64)
        bid = jnp.zeros((n,), jnp.int64)
        for l in range(L):
            bid = bid * jnp.int64(1000003) + h[:, l]
        _, counts = jnp.unique(bid, return_counts=True, size=n, fill_value=-1)
        cap_r = cap or int(jnp.max(counts))
        rho, order, inv, bs, pts_s, st_p, npad = _bucket_round(
            points, sub, d_cut, L, cap_r, block)
        rho_best = jnp.maximum(rho_best, rho)
        rounds.append((order, inv, bs, pts_s, st_p, cap_r))

    rho_key = with_jitter(rho_best)
    # dependent search within each round's buckets
    best_delta = jnp.full((n,), jnp.inf)
    best_parent = jnp.full((n,), -1, jnp.int32)
    for order, inv, bs, pts_s, st_p, cap_r in rounds:
        rk_s = rho_key[order]
        dlt, par = _bucket_dependent(pts_s, rk_s, bs, st_p, cap_r, block)
        dlt = dlt[inv]
        par_orig = jnp.where(par >= 0, order[jnp.maximum(par, 0)], -1)[inv]
        better = dlt < best_delta
        best_delta = jnp.where(better, dlt, best_delta)
        best_parent = jnp.where(better, par_orig, best_parent).astype(jnp.int32)

    # full-scan fallback for points with no denser point in any bucket
    unresolved = np.nonzero(~np.isfinite(np.asarray(best_delta)))[0]
    if unresolved.size:
        m = _pow2_pad(unresolved.size)
        qs = np.pad(unresolved, (0, m - unresolved.size))
        fd, fp = masked_nn_rows(points[qs], rho_key[qs], points, rho_key,
                                block=fallback_block)
        bd = np.asarray(best_delta).copy()
        bp = np.asarray(best_parent).copy()
        fdv = np.asarray(fd)[: unresolved.size]
        bd[unresolved] = np.where(np.isfinite(fdv), fdv, np.inf)
        bp[unresolved] = np.asarray(fp)[: unresolved.size]
        best_delta, best_parent = jnp.asarray(bd), jnp.asarray(bp)

    return DPCResult(rho=rho_best, rho_key=rho_key, delta=best_delta,
                     parent=best_parent.astype(jnp.int32))


@partial(jax.jit, static_argnames=("cap", "block"))
def _bucket_dependent(pts_s, rk_s, bs, st_p, cap: int, block: int):
    n = pts_s.shape[0]
    nb = -(-n // block)
    npad = nb * block
    pts_p = jnp.pad(pts_s, ((0, npad - n), (0, 0)))
    rk_p = jnp.pad(rk_s, (0, npad - n), constant_values=jnp.inf)

    def chunk(i0):
        rows = jax.lax.dynamic_slice_in_dim(pts_p, i0, block, 0)
        rks = jax.lax.dynamic_slice_in_dim(rk_p, i0, block, 0)
        st = jax.lax.dynamic_slice_in_dim(st_p, i0, block, 0)
        rowi = i0 + jnp.arange(block)
        idx = st[:, None] + jnp.arange(cap)
        same = (idx < n) & (bs[jnp.minimum(idx, n - 1)] ==
                            bs[jnp.minimum(rowi, n - 1)][:, None])
        cand = pts_s[jnp.minimum(idx, n - 1)]
        crk = rk_s[jnp.minimum(idx, n - 1)]
        d2 = jnp.sum((rows[:, None, :] - cand) ** 2, -1)
        d2 = jnp.where(same & (crk > rks[:, None]), d2, jnp.inf)
        j = jnp.argmin(d2, axis=1)
        best = d2[jnp.arange(block), j]
        par = jnp.minimum(idx, n - 1)[jnp.arange(block), j]
        return jnp.sqrt(best), jnp.where(jnp.isfinite(best), par, -1).astype(jnp.int32)

    dlt, par = jax.lax.map(chunk, jnp.arange(nb) * block)
    return dlt.reshape(-1)[:n], par.reshape(-1)[:n]
