"""Public DPC API: one config, one entry point, all algorithms."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Literal

import jax.numpy as jnp

from .approxdpc import run_approxdpc
from .cfsfdp_a import run_cfsfdp_a
from .dpc_types import DPCResult
from .exdpc import run_exdpc
from .labels import Clustering, assign_labels, decision_graph
from .lsh_ddp import run_lsh_ddp
from .sapproxdpc import run_sapproxdpc
from .scan import run_scan

Algorithm = Literal["scan", "exdpc", "approxdpc", "sapproxdpc",
                    "lsh_ddp", "cfsfdp_a"]


@dataclass(frozen=True)
class DPCConfig:
    """One config for every DPC algorithm.

    ``backend`` selects the kernel backend for the two hot primitives
    (range count / denser-NN, see repro.kernels.backend):

    * ``None`` (default) — platform auto-detection: the Pallas MXU kernels
      on TPU, the pure-jnp stencil/scan reference elsewhere.
    * ``"jnp"`` — force the blocked direct-difference reference.
    * ``"pallas"`` — force the Mosaic TPU kernels (dense tiled formulation).
    * ``"pallas-interpret"`` — the same kernels under the Pallas interpreter
      (CPU CI; slow, correctness only).

    Applies to ``scan``/``exdpc``/``approxdpc``/``sapproxdpc``; the LSH-DDP
    and CFSFDP-A baselines always run their own reference math.

    ``layout`` selects the dense-engine execution mode:

    * ``None`` / ``"dense"`` — the all-pairs tile sweep.
    * ``"block-sparse"`` — the grid-pruned worklist mode: the driver runs
      the fused primitive on the grid-sorted table and only tile pairs
      within d_cut of each other's bounding boxes (plus the NN ring) touch
      the hardware.  Bit-identical results, sub-quadratic tile work under
      the paper's d_cut assumption; forces the dense-engine path even on
      the ``jnp`` backend (whose worklists are jit-built).
    """

    d_cut: float
    rho_min: float = 10.0
    delta_min: float | None = None      # default 2 * d_cut (must be > d_cut)
    algorithm: Algorithm = "approxdpc"
    eps: float = 0.8                    # S-Approx-DPC only
    grid_dims: int | None = None        # candidate-grid dims (default min(d,3))
    block: int = 256
    backend: str | None = None          # kernel backend (see class docstring)
    layout: str | None = None           # dense | block-sparse (see docstring)

    def resolved_delta_min(self) -> float:
        dm = 2.0 * self.d_cut if self.delta_min is None else self.delta_min
        if dm <= self.d_cut:
            raise ValueError("delta_min must exceed d_cut (Def. 5)")
        return dm


_RUNNERS = {
    "scan": lambda p, c: run_scan(p, c.d_cut, block=max(c.block, 256),
                                  backend=c.backend, layout=c.layout),
    "exdpc": lambda p, c: run_exdpc(p, c.d_cut, g=c.grid_dims, block=c.block,
                                    backend=c.backend, layout=c.layout),
    "approxdpc": lambda p, c: run_approxdpc(p, c.d_cut, g=c.grid_dims,
                                            block=c.block, backend=c.backend,
                                            layout=c.layout),
    "sapproxdpc": lambda p, c: run_sapproxdpc(p, c.d_cut, eps=c.eps,
                                              g=c.grid_dims, block=c.block,
                                              backend=c.backend,
                                              layout=c.layout),
    "lsh_ddp": lambda p, c: run_lsh_ddp(p, c.d_cut),
    "cfsfdp_a": lambda p, c: run_cfsfdp_a(p, c.d_cut),
}


def compute_dpc(points, config: DPCConfig) -> DPCResult:
    """rho/delta/dependent-point computation with the configured algorithm."""
    return _RUNNERS[config.algorithm](jnp.asarray(points, jnp.float32), config)


def cluster(points, config: DPCConfig) -> tuple[Clustering, DPCResult]:
    res = compute_dpc(points, config)
    out = assign_labels(res, config.rho_min, config.resolved_delta_min())
    return out, res


__all__ = ["DPCConfig", "DPCResult", "Clustering", "compute_dpc", "cluster",
           "assign_labels", "decision_graph"]
