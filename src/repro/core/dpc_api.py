"""Public DPC API: one config, one entry point, all algorithms.

.. deprecated:: the execution axes of :class:`DPCConfig` (``backend`` /
   ``layout`` / ``block``) are legacy shims over one
   :class:`repro.engine.ExecSpec` — pass ``exec_spec=ExecSpec(...)``
   instead, or use the :class:`repro.engine.DPCEngine` facade, which also
   covers streaming (``partial_fit``) and read-only ``predict`` queries.
   The algorithm-selection fields (``d_cut`` / ``algorithm`` / ``rho_min``
   ...) are not deprecated.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import jax.numpy as jnp

from repro.engine.spec import ExecSpec, merge_legacy

from .approxdpc import run_approxdpc
from .cfsfdp_a import run_cfsfdp_a
from .dpc_types import DPCResult
from .exdpc import run_exdpc
from .labels import Clustering, assign_labels, decision_graph
from .lsh_ddp import run_lsh_ddp
from .sapproxdpc import run_sapproxdpc
from .scan import run_scan

Algorithm = Literal["scan", "exdpc", "approxdpc", "sapproxdpc",
                    "lsh_ddp", "cfsfdp_a"]

_ALGORITHMS = ("scan", "exdpc", "approxdpc", "sapproxdpc", "lsh_ddp",
               "cfsfdp_a")


@dataclass(frozen=True)
class DPCConfig:
    """One config for every DPC algorithm.

    Execution is configured by ``exec_spec`` (a
    :class:`repro.engine.ExecSpec`: backend x layout x precision x block x
    data_axis — see that class for the axes).  The ``backend`` / ``layout``
    / ``block`` fields are the legacy spellings of the same axes; they fold
    into one ExecSpec with a ``DeprecationWarning`` and may not conflict
    with an explicitly-passed ``exec_spec``.

    Applies to ``scan``/``exdpc``/``approxdpc``/``sapproxdpc``; the LSH-DDP
    and CFSFDP-A baselines always run their own reference math.

    Validation is fail-fast: unknown algorithm names, non-positive
    ``d_cut``, and ``eps <= 0`` for S-Approx-DPC raise ``ValueError`` here,
    not deep inside the kernel layer.
    """

    d_cut: float
    rho_min: float = 10.0
    delta_min: float | None = None      # default 2 * d_cut (must be > d_cut)
    algorithm: Algorithm = "approxdpc"
    eps: float = 0.8                    # S-Approx-DPC only
    grid_dims: int | None = None        # candidate-grid dims (default min(d,3))
    exec_spec: ExecSpec | None = None   # the unified execution axes
    block: int | None = None            # deprecated -> ExecSpec.block
    backend: str | None = None          # deprecated -> ExecSpec.backend
    layout: str | None = None           # deprecated -> ExecSpec.layout

    def __post_init__(self):
        if self.algorithm not in _ALGORITHMS:
            raise ValueError(f"unknown algorithm {self.algorithm!r}; "
                             f"expected one of {_ALGORITHMS}")
        if not self.d_cut > 0.0:
            raise ValueError(f"d_cut must be positive, got {self.d_cut!r}")
        if self.algorithm == "sapproxdpc" and self.eps <= 0.0:
            raise ValueError(f"S-Approx-DPC needs eps > 0 (coarse-grid side "
                             f"eps*d_cut/sqrt(d)); got {self.eps!r}")
        object.__setattr__(self, "exec_spec", merge_legacy(
            self.exec_spec, owner="DPCConfig", backend=self.backend,
            layout=self.layout, block=self.block))

    def resolved_exec(self) -> ExecSpec:
        return self.exec_spec

    def resolved_delta_min(self) -> float:
        dm = 2.0 * self.d_cut if self.delta_min is None else self.delta_min
        if dm <= self.d_cut:
            raise ValueError("delta_min must exceed d_cut (Def. 5)")
        return dm


_RUNNERS = {
    "scan": lambda p, c, x: run_scan(p, c.d_cut, exec_spec=x),
    "exdpc": lambda p, c, x: run_exdpc(p, c.d_cut, g=c.grid_dims,
                                       exec_spec=x),
    "approxdpc": lambda p, c, x: run_approxdpc(p, c.d_cut, g=c.grid_dims,
                                               exec_spec=x),
    "sapproxdpc": lambda p, c, x: run_sapproxdpc(p, c.d_cut, eps=c.eps,
                                                 g=c.grid_dims, exec_spec=x),
    "lsh_ddp": lambda p, c, x: run_lsh_ddp(p, c.d_cut),
    "cfsfdp_a": lambda p, c, x: run_cfsfdp_a(p, c.d_cut),
}


def compute_dpc(points, config: DPCConfig) -> DPCResult:
    """rho/delta/dependent-point computation with the configured algorithm."""
    return _RUNNERS[config.algorithm](jnp.asarray(points, jnp.float32),
                                      config, config.resolved_exec())


def cluster(points, config: DPCConfig) -> tuple[Clustering, DPCResult]:
    res = compute_dpc(points, config)
    out = assign_labels(res, config.rho_min, config.resolved_delta_min())
    return out, res


__all__ = ["DPCConfig", "DPCResult", "Clustering", "compute_dpc", "cluster",
           "assign_labels", "decision_graph"]
