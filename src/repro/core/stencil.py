"""Grid-stencil range counting and higher-density NN search.

These are the pure-jnp reference forms of the two compute hot spots the paper
optimizes (local density = range count; dependent point = constrained NN).
``repro.kernels`` provides the Pallas TPU versions; tests assert equality.

All functions operate in *sorted* (grid) order and are blocked with ``lax.map``
so memory stays O(block * stencil_window).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .grid import Grid, cell_span_bounds, point_span_bounds


def _pad_to(x: jnp.ndarray, m: int, axis: int = 0, value=0):
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, m - x.shape[axis])
    return jnp.pad(x, pad, constant_values=value)


@partial(jax.jit, static_argnames=("block",))
def density_per_point(grid: Grid, block: int = 256) -> jnp.ndarray:
    """Exact rho per *sorted* point via per-point stencil gathers.

    This is the Ex-DPC analogue of "one range search per point": every point
    gathers its own candidate spans.  O(n * S * W) with S = 3^(g-1) spans of
    padded width W = grid.span_cap.
    """
    n, d = grid.points.shape
    starts, ends = point_span_bounds(grid)                    # (n, S)
    S = starts.shape[1]
    W = grid.span_cap
    d2cut = jnp.float32(grid.d_cut) ** 2
    nb = -(-n // block)
    pts_p = _pad_to(grid.points, nb * block)
    st_p = _pad_to(starts, nb * block)
    en_p = _pad_to(ends, nb * block)

    def chunk(i0):
        rows = jax.lax.dynamic_slice_in_dim(pts_p, i0, block, 0)      # (B, d)
        st = jax.lax.dynamic_slice_in_dim(st_p, i0, block, 0)         # (B, S)
        en = jax.lax.dynamic_slice_in_dim(en_p, i0, block, 0)
        idx = st[..., None] + jnp.arange(W, dtype=st.dtype)           # (B, S, W)
        valid = idx < en[..., None]
        cand = grid.points[jnp.minimum(idx, n - 1)]                   # (B, S, W, d)
        d2 = jnp.sum((rows[:, None, None, :] - cand) ** 2, axis=-1)
        return jnp.sum((d2 < d2cut) & valid, axis=(1, 2))

    cnt = jax.lax.map(chunk, jnp.arange(nb) * block).reshape(-1)[:n]
    return cnt.astype(jnp.float32)


@partial(jax.jit, static_argnames=("block",))
def density_per_cell(grid: Grid, block: int = 32) -> jnp.ndarray:
    """Exact rho per sorted point via *joint* per-cell gathers (Approx-DPC §4.2).

    All members of a candidate cell share one gather of the cell's stencil
    spans — the TPU formulation of the paper's joint range search (one
    enlarged search serves the whole cell).  Returns rho in sorted order.
    """
    n, d = grid.points.shape
    starts, ends = cell_span_bounds(grid)                     # (n, S) padded cells
    S = starts.shape[1]
    W = grid.span_cap
    M = grid.cell_cap
    d2cut = jnp.float32(grid.d_cut) ** 2
    nc = grid.num_cells
    nb = -(-nc // block)
    st_p = _pad_to(starts[:nc], nb * block)
    en_p = _pad_to(ends[:nc], nb * block)
    cs_p = _pad_to(grid.cell_start[:nc], nb * block, value=n)
    cc_p = _pad_to(grid.cell_count[:nc], nb * block)

    def chunk(i0):
        cst = jax.lax.dynamic_slice_in_dim(cs_p, i0, block, 0)        # (B,)
        ccnt = jax.lax.dynamic_slice_in_dim(cc_p, i0, block, 0)
        st = jax.lax.dynamic_slice_in_dim(st_p, i0, block, 0)         # (B, S)
        en = jax.lax.dynamic_slice_in_dim(en_p, i0, block, 0)
        midx = cst[:, None] + jnp.arange(M, dtype=cst.dtype)          # (B, M)
        mvalid = jnp.arange(M) < ccnt[:, None]
        members = grid.points[jnp.minimum(midx, n - 1)]               # (B, M, d)
        cidx = st[..., None] + jnp.arange(W, dtype=st.dtype)          # (B, S, W)
        cvalid = cidx < en[..., None]
        cand = grid.points[jnp.minimum(cidx, n - 1)]                  # (B, S, W, d)
        cand = cand.reshape(block, S * W, d)
        cvalid = cvalid.reshape(block, S * W)
        d2 = jnp.sum((members[:, :, None, :] - cand[:, None, :, :]) ** 2, -1)
        cnt = jnp.sum((d2 < d2cut) & cvalid[:, None, :], axis=-1)     # (B, M)
        return cnt, midx, mvalid

    cnts, midxs, mvalids = jax.lax.map(chunk, jnp.arange(nb) * block)
    flat_idx = jnp.where(mvalids.reshape(-1), midxs.reshape(-1), n)
    rho = jnp.zeros((n,), jnp.float32).at[flat_idx].set(
        cnts.reshape(-1).astype(jnp.float32), mode="drop")
    return rho


@partial(jax.jit, static_argnames=("block",))
def dependent_stencil(grid: Grid, rho_key_sorted: jnp.ndarray, block: int = 256):
    """Nearest higher-density point within the d_cut stencil, per sorted point.

    Returns (delta, parent_sorted_idx, resolved).  Where ``resolved`` is True,
    delta/parent are *exact* (the true dependent point must lie within d_cut,
    hence inside the stencil — DESIGN.md §3).  Where False, no higher-density
    point exists within d_cut and the caller must run the global fallback.
    """
    n, d = grid.points.shape
    starts, ends = point_span_bounds(grid)
    S = starts.shape[1]
    W = grid.span_cap
    d2cut = jnp.float32(grid.d_cut) ** 2
    nb = -(-n // block)
    pts_p = _pad_to(grid.points, nb * block)
    rk_p = _pad_to(rho_key_sorted, nb * block, value=jnp.inf)
    st_p = _pad_to(starts, nb * block)
    en_p = _pad_to(ends, nb * block)

    def chunk(i0):
        rows = jax.lax.dynamic_slice_in_dim(pts_p, i0, block, 0)
        rk = jax.lax.dynamic_slice_in_dim(rk_p, i0, block, 0)
        st = jax.lax.dynamic_slice_in_dim(st_p, i0, block, 0)
        en = jax.lax.dynamic_slice_in_dim(en_p, i0, block, 0)
        idx = st[..., None] + jnp.arange(W, dtype=st.dtype)           # (B,S,W)
        valid = idx < en[..., None]
        idx_c = jnp.minimum(idx, n - 1)
        cand = grid.points[idx_c]                                     # (B,S,W,d)
        cand_rk = rho_key_sorted[idx_c]
        d2 = jnp.sum((rows[:, None, None, :] - cand) ** 2, axis=-1)
        mask = valid & (cand_rk > rk[:, None, None]) & (d2 < d2cut)
        d2m = jnp.where(mask, d2, jnp.inf).reshape(block, S * W)
        j = jnp.argmin(d2m, axis=1)
        best = d2m[jnp.arange(block), j]
        pidx = idx_c.reshape(block, S * W)[jnp.arange(block), j]
        resolved = jnp.isfinite(best)
        return (jnp.sqrt(best), jnp.where(resolved, pidx, -1).astype(jnp.int32),
                resolved)

    delta, parent, resolved = jax.lax.map(chunk, jnp.arange(nb) * block)
    return delta.reshape(-1)[:n], parent.reshape(-1)[:n], resolved.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("block",))
def density_for_slots(grid: Grid, slots: jnp.ndarray, block: int = 256) -> jnp.ndarray:
    """Exact rho for a subset of sorted slots (S-Approx-DPC representatives).

    ``slots`` is padded with n (out of range) — padded rows return 0.
    """
    n, d = grid.points.shape
    starts_all, ends_all = point_span_bounds(grid)
    S = starts_all.shape[1]
    W = grid.span_cap
    d2cut = jnp.float32(grid.d_cut) ** 2
    m = slots.shape[0]
    nb = -(-m // block)
    sl_p = _pad_to(slots, nb * block, value=n)

    def chunk(i0):
        sl = jax.lax.dynamic_slice_in_dim(sl_p, i0, block, 0)
        alive = sl < n
        slc = jnp.minimum(sl, n - 1)
        rows = grid.points[slc]
        st = starts_all[slc]
        en = ends_all[slc]
        idx = st[..., None] + jnp.arange(W, dtype=st.dtype)
        valid = idx < en[..., None]
        cand = grid.points[jnp.minimum(idx, n - 1)]
        d2 = jnp.sum((rows[:, None, None, :] - cand) ** 2, axis=-1)
        cnt = jnp.sum((d2 < d2cut) & valid, axis=(1, 2))
        return jnp.where(alive, cnt, 0)

    cnt = jax.lax.map(chunk, jnp.arange(nb) * block).reshape(-1)[:m]
    return cnt.astype(jnp.float32)


@partial(jax.jit, static_argnames=("block",))
def dependent_stencil_slots(grid: Grid, rho_key_sorted: jnp.ndarray,
                            slots: jnp.ndarray, block: int = 256):
    """dependent_stencil restricted to query rows ``slots`` (padded with n).

    Candidates whose rho_key is -inf never match, so callers can restrict the
    candidate set (e.g. to representatives) by masking rho_key_sorted.
    """
    n, d = grid.points.shape
    starts_all, ends_all = point_span_bounds(grid)
    S = starts_all.shape[1]
    W = grid.span_cap
    d2cut = jnp.float32(grid.d_cut) ** 2
    m = slots.shape[0]
    nb = -(-m // block)
    sl_p = _pad_to(slots, nb * block, value=n)

    def chunk(i0):
        sl = jax.lax.dynamic_slice_in_dim(sl_p, i0, block, 0)
        alive = sl < n
        slc = jnp.minimum(sl, n - 1)
        rows = grid.points[slc]
        rk = jnp.where(alive, rho_key_sorted[slc], jnp.inf)
        st = starts_all[slc]
        en = ends_all[slc]
        idx = st[..., None] + jnp.arange(W, dtype=st.dtype)
        valid = idx < en[..., None]
        idx_c = jnp.minimum(idx, n - 1)
        cand = grid.points[idx_c]
        cand_rk = rho_key_sorted[idx_c]
        d2 = jnp.sum((rows[:, None, None, :] - cand) ** 2, axis=-1)
        mask = valid & (cand_rk > rk[:, None, None]) & (d2 < d2cut)
        d2m = jnp.where(mask, d2, jnp.inf).reshape(block, S * W)
        j = jnp.argmin(d2m, axis=1)
        best = d2m[jnp.arange(block), j]
        pidx = idx_c.reshape(block, S * W)[jnp.arange(block), j]
        resolved = jnp.isfinite(best)
        return (jnp.sqrt(best), jnp.where(resolved, pidx, -1).astype(jnp.int32),
                resolved)

    delta, parent, resolved = jax.lax.map(chunk, jnp.arange(nb) * block)
    return delta.reshape(-1)[:m], parent.reshape(-1)[:m], resolved.reshape(-1)[:m]


@partial(jax.jit, static_argnames=("block",))
def masked_nn_rows(query_pts, query_rk, all_pts, all_rk, block: int = 4096):
    """Exact NN among strictly-denser points, query rows vs the full set.

    The global fallback for stencil-unresolved points (paper Lemma 2's
    (1-alpha) case). O(m * n), m = number of query rows.
    """
    m = query_pts.shape[0]
    n = all_pts.shape[0]
    nb = -(-n // block)
    pts_p = _pad_to(all_pts, nb * block)
    rk_p = _pad_to(all_rk, nb * block, value=-jnp.inf)

    def col_block(j0):
        cols = jax.lax.dynamic_slice_in_dim(pts_p, j0, block, 0)
        crk = jax.lax.dynamic_slice_in_dim(rk_p, j0, block, 0)
        d2 = jnp.sum((query_pts[:, None, :] - cols[None, :, :]) ** 2, -1)
        d2 = jnp.where(crk[None, :] > query_rk[:, None], d2, jnp.inf)
        j = jnp.argmin(d2, axis=1)
        return d2[jnp.arange(m), j], (j0 + j).astype(jnp.int32)

    d2s, js = jax.lax.map(col_block, jnp.arange(nb) * block)   # (nb, m)
    k = jnp.argmin(d2s, axis=0)
    best = d2s[k, jnp.arange(m)]
    parent = jnp.where(jnp.isfinite(best), js[k, jnp.arange(m)], -1)
    return jnp.sqrt(best), parent
