"""Shared DPC result types and the density tie-break rule.

The paper assumes all local densities are distinct, "which is practically
possible by adding a random value in (0,1) to rho_i" (§3).  We use a
*deterministic* jitter — a fixed pseudo-random permutation of point indices
scaled into (0,1) — so results are reproducible and checkpoint/restart replays
bit-identically (DESIGN.md §9.4).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

_KNUTH = 2654435761  # Fibonacci hashing multiplier


class DPCResult(NamedTuple):
    rho: jnp.ndarray     # (n,) float32 — integer local density (self included)
    rho_key: jnp.ndarray  # (n,) float32 — rho + jitter, all-distinct comparison key
    delta: jnp.ndarray   # (n,) float32 — dependent distance (inf for global peak)
    parent: jnp.ndarray  # (n,) int32 — dependent point (original index); -1 = none


def density_jitter(n: int) -> jnp.ndarray:
    """Deterministic all-distinct jitter in (0, 1), one value per point."""
    idx = jnp.arange(n, dtype=jnp.uint32)
    h = (idx * jnp.uint32(_KNUTH)) ^ (idx >> 13)
    # distinct ranks -> distinct jitter; +0.5 keeps it strictly inside (0,1)
    rank = jnp.argsort(jnp.argsort(h))
    return (rank.astype(jnp.float32) + 0.5) / jnp.float32(n)


def with_jitter(rho: jnp.ndarray) -> jnp.ndarray:
    return rho.astype(jnp.float32) + density_jitter(rho.shape[0])
