"""Ex-DPC (§3): the exact algorithm, TPU-adapted.

Paper mechanism: kd-tree range search for rho; incrementally-rebuilt kd-tree
over density-sorted points for delta (which the paper proves cannot be
parallelized).  Two exact realizations, selected by the kernel backend:

* ``jnp`` (reference): grid-stencil range count for rho; for delta, the
  invariant "the tree contains exactly the denser points" becomes a *static
  masked search* — first the d_cut stencil (exact whenever a denser point
  exists within d_cut, i.e. the paper's Lemma-2 alpha fraction), then a
  global masked-NN fallback for the few stencil-unresolved points.
* ``pallas`` / ``pallas-interpret`` (dense MXU): the fused ``rho_delta``
  engine primitive — one tile sweep computes the range count AND the
  denser-NN accumulator (kernels/sweep.py); the incremental-tree invariant
  becomes the kept-k resolution plus a masked-NN pass over the local-maxima
  tail.  (The triangular prefix-NN kernel remains on the backend as an
  alternative schedule.)

Output is exact either way — bit-equal to the O(n^2) Scan oracle (tested;
the pallas form up to f32 threshold rounding, see kernels/backend.py).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro import obs
from repro.engine.planner import as_plan
from repro.kernels.backend import get_backend

from .dpc_types import DPCResult, density_jitter, with_jitter
from .grid import build_grid, Grid, unsort_dpc
from .stencil import density_per_point, dependent_stencil


def _pow2_pad(m: int) -> int:
    p = 1
    while p < m:
        p *= 2
    return p


def resolve_fallback(points, rho_key, delta, parent, resolved, block=4096,
                     backend=None):
    """Global denser-NN for stencil-unresolved rows (host-orchestrated)."""
    be = get_backend(backend)
    unresolved = np.asarray(~resolved).nonzero()[0]
    if unresolved.size == 0:
        return delta, parent
    m = _pow2_pad(unresolved.size)
    rows = np.pad(unresolved, (0, m - unresolved.size))
    q_pts = points[rows]
    q_rk = jnp.asarray(rho_key)[rows]
    fdelta, fparent = be.denser_nn(q_pts, q_rk, points, rho_key, block=block)
    fdelta = np.asarray(fdelta)[: unresolved.size]
    fparent = np.asarray(fparent)[: unresolved.size]
    delta = np.asarray(delta).copy()
    parent = np.asarray(parent).copy()
    # the single global density peak keeps delta = inf, parent = -1 (Def. 3)
    delta[unresolved] = np.where(np.isfinite(fdelta), fdelta, np.inf)
    parent[unresolved] = fparent
    return jnp.asarray(delta), jnp.asarray(parent)


def _run_exdpc_dense(points, d_cut: float, pl,
                     grid: Grid | None = None,
                     g: int | None = None) -> DPCResult:
    """Dense-engine path: the fused rho+delta tile sweep.

    One engine invocation computes the range count and the denser-NN
    accumulator over the same distance tiles (kernels/sweep.py) — no
    density sort, no second sweep.  With the plan's block-sparse layout
    the sweep runs on the grid-sorted table (compact tile AABBs ->
    grid-pruned worklist) and results map back through ``grid.unsort_dpc``.
    The triangular ``prefix_nn`` form remains available on the backend for
    schedule experiments (benchmarks/backend_compare.py still times it)."""
    n = points.shape[0]
    if pl.grid_sort:
        if grid is None:
            with obs.span("exdpc.grid", n=n) as sp:
                grid = sp.sync(build_grid(points, d_cut, g=g))
        with obs.span("exdpc.rho_delta", n=n, layout=pl.layout) as sp:
            rho_s, rk_s, dd_s, pp_s = pl.rho_delta(
                grid.points, grid.points, d_cut,
                jitter=density_jitter(n)[grid.order])
            rho, rho_key, delta, parent = sp.sync(
                unsort_dpc(grid, rho_s, rk_s, dd_s, pp_s))
        return DPCResult(rho=rho, rho_key=rho_key, delta=delta,
                         parent=parent)
    with obs.span("exdpc.rho_delta", n=n, layout=pl.layout) as sp:
        rho, rho_key, delta, parent = sp.sync(pl.rho_delta(
            points, points, d_cut, jitter=density_jitter(n)))
    return DPCResult(rho=rho, rho_key=rho_key, delta=delta,
                     parent=parent.astype(jnp.int32))


def run_exdpc(points, d_cut: float, *, g: int | None = None,
              fallback_block: int = 4096,
              grid: Grid | None = None, exec_spec=None) -> DPCResult:
    points = jnp.asarray(points, jnp.float32)
    pl = as_plan(exec_spec, points)
    if pl.backend.mxu_dense or pl.sparse:
        return _run_exdpc_dense(points, d_cut, pl, grid=grid, g=g)

    block = pl.block or 256     # stencil row-tile default (jnp path)
    if grid is None:
        with obs.span("exdpc.grid", n=points.shape[0]) as sp:
            grid = sp.sync(build_grid(points, d_cut, g=g))

    with obs.span("exdpc.rho", n=points.shape[0]) as sp:
        rho_sorted = density_per_point(grid, block=block)
        rho = sp.sync(rho_sorted[grid.inv_order])
    rho_key = with_jitter(rho)

    rk_sorted = rho_key[grid.order]
    with obs.span("exdpc.stencil", n=points.shape[0]) as sp:
        delta_s, parent_s, resolved_s = dependent_stencil(grid, rk_sorted,
                                                          block=block)
        # back to original indexing
        delta = delta_s[grid.inv_order]
        parent_sorted = parent_s[grid.inv_order]
        parent = jnp.where(parent_sorted >= 0, grid.order[parent_sorted],
                           -1).astype(jnp.int32)
        resolved = sp.sync(resolved_s[grid.inv_order])

    with obs.span("exdpc.fallback") as sp:
        delta, parent = sp.sync(resolve_fallback(
            points, rho_key, delta, parent, resolved,
            block=fallback_block, backend=pl.backend))
    return DPCResult(rho=rho, rho_key=rho_key, delta=delta,
                     parent=parent.astype(jnp.int32))
