"""Noise/center selection (Defs. 4-5) and cluster label propagation (Def. 6).

The paper propagates labels by DFS from each center.  DFS is sequential; the
TPU-native equivalent is pointer jumping (path doubling) on the dependency
forest: ``parent <- parent[parent]`` for ceil(log2 n) rounds.  Chains ascend
strictly in density, so the forest is acyclic and every non-noise point reaches
its center; noise (rho < rho_min) can only depend on denser noise, so noise
never contaminates a cluster (DESIGN.md §3).
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro import obs

from .dpc_types import DPCResult


class Clustering(NamedTuple):
    labels: jnp.ndarray    # (n,) int32 — cluster id 0..k-1, -1 for noise
    centers: jnp.ndarray   # (n,) bool  — cluster-center mask
    num_clusters: jnp.ndarray  # () int32


def select_centers(res: DPCResult, rho_min: float, delta_min: float):
    noise = res.rho < rho_min
    centers = (~noise) & (res.delta >= delta_min)
    return centers, noise


@jax.jit
def _propagate(parent: jnp.ndarray, roots: jnp.ndarray) -> jnp.ndarray:
    """Pointer-jump until every point points at its root (roots are self-loops)."""
    n = parent.shape[0]
    p = jnp.where(roots, jnp.arange(n, dtype=parent.dtype), parent)
    # global density peak has parent -1; make it a self-loop root as well
    p = jnp.where(p < 0, jnp.arange(n, dtype=parent.dtype), p)
    steps = max(int(math.ceil(math.log2(max(n, 2)))), 1)

    def body(p, _):
        return p[p], None

    p, _ = jax.lax.scan(body, p, None, length=steps)
    return p


def assign_labels(res: DPCResult, rho_min: float, delta_min: float) -> Clustering:
    with obs.span("labels.assign") as sp:
        centers, noise = select_centers(res, rho_min, delta_min)
        root = _propagate(res.parent, centers)
        # densify center ids -> cluster labels 0..k-1
        cid = jnp.cumsum(centers.astype(jnp.int32)) - 1       # label at center slots
        labels = cid[root]
        # a point whose root is not a center (its chain tops out at a noise peak
        # or the global peak below delta_min) is unassigned -> noise
        reached = centers[root]
        labels = jnp.where(noise | ~reached, -1, labels).astype(jnp.int32)
        sp.sync(labels)
    return Clustering(labels=labels, centers=centers,
                      num_clusters=jnp.sum(centers.astype(jnp.int32)))


def decision_graph(res: DPCResult):
    """(rho_i, delta_i) pairs for the paper's Fig. 1 decision graph."""
    return jnp.stack([res.rho, res.delta], axis=-1)
