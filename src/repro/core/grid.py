"""Uniform-grid cell lists: the TPU-native replacement for the paper's kd-tree.

The paper indexes P with a kd-tree (range search / NN search) plus, for
Approx-DPC, a uniform grid G with cell side d_cut/sqrt(d).  Pointer-chased
trees do not map to TPU, so this module provides the adapted structure used by
every algorithm in ``repro.core``:

* a *grouping* grid with side ``d_cut/sqrt(d)`` over all ``d`` dims — same-cell
  diameter < d_cut, exactly the paper's G (used by Approx-DPC rule 1 and
  S-Approx-DPC representatives);
* a *candidate* grid over ``g = min(d, 3)`` leading dims with side
  ``ceil(sqrt(d)) * d_cut/sqrt(d) >= d_cut`` — any point within Euclidean
  distance d_cut lies in one of the 3^g neighbouring candidate cells, so a
  radius-d_cut search is a gather over a **constant stencil** of cells.  The
  candidate grid is a coarsening of the grouping grid on the leading dims, so a
  single sort by (candidate-cell, grouping-cell) key makes *both* partitions
  contiguous.  Stencil cells that share a (g-1)-prefix are merged into one
  contiguous span, so a search touches only ``3^(g-1)`` gathers.

All arrays are fixed-shape; capacities (max span length, max members per cell)
are measured at build time on the host, which is the standard JAX cell-list
pattern (capacities are data statistics, not traced values).

Cell boundaries are *canonical*: coordinates quantize as ``floor(p / side)``
against the absolute origin, not against the data minimum.  Two point sets
that share points therefore agree on which points share a cell — the property
``repro.stream`` relies on to keep an incrementally-maintained partition
bit-identical to a from-scratch ``build_grid`` of the same window contents
(a data-min origin shifts every boundary whenever the minimum point expires).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclass(frozen=True)
class Grid:
    """Sorted cell-list view of a point set (all indices refer to sorted order).

    Array fields are pytree children; capacities/dims are static metadata so
    jitted consumers specialize on them (they shape the gathers).
    """

    points: jnp.ndarray        # (n, d) float32, sorted by (candidate, grouping) key
    order: jnp.ndarray         # (n,)  original index of sorted slot i
    inv_order: jnp.ndarray     # (n,)  sorted slot of original index i
    cand_key: jnp.ndarray      # (n,)  int64 candidate-cell key, non-decreasing
    group_key: jnp.ndarray     # (n,)  int64 grouping-cell key (refines cand_key order)
    cand_coords: jnp.ndarray   # (n, g) int32 candidate-cell coords per point
    cand_extent: jnp.ndarray   # (g,)  int64 number of candidate cells per dim
    cand_strides: jnp.ndarray  # (g,)  int64 mixed-radix strides of cand key
    # Unique candidate cells (padded to n with sentinel key):
    cell_keys: jnp.ndarray     # (n,) int64, unique candidate keys ascending then sentinel
    cell_start: jnp.ndarray    # (n,) int32 first sorted slot of each cell
    cell_count: jnp.ndarray    # (n,) int32 members per cell
    point_cell: jnp.ndarray    # (n,) int32 unique-cell index of each sorted point
    num_cells: int = field(metadata=dict(static=True))  # python int (static)
    # static capacities
    span_cap: int = field(metadata=dict(static=True))   # max span length
    cell_cap: int = field(metadata=dict(static=True))   # max members per cell
    g: int = field(metadata=dict(static=True))          # gridded dims
    d: int = field(metadata=dict(static=True))
    d_cut: float = field(metadata=dict(static=True))


SENTINEL = jnp.iinfo(jnp.int64).max


def _num_prefix_offsets(g: int) -> int:
    return 3 ** max(g - 1, 0)


def prefix_offsets(g: int) -> np.ndarray:
    """All {-1,0,1}^(g-1) offsets over the leading g-1 candidate dims."""
    if g <= 1:
        return np.zeros((1, 0), dtype=np.int64)
    grids = np.meshgrid(*([np.array([-1, 0, 1])] * (g - 1)), indexing="ij")
    return np.stack([a.ravel() for a in grids], axis=-1).astype(np.int64)


def group_side(d_cut: float, d: int) -> float:
    """Side of the grouping grid G: d_cut/sqrt(d) (in-cell diameter < d_cut)."""
    return d_cut / math.sqrt(d)


def canonical_group_coords(points: jnp.ndarray, d_cut: float) -> jnp.ndarray:
    """Canonical (absolute-origin) grouping-cell coordinates, (n, d) int64.

    The single quantization rule shared by ``build_grid`` and the streaming
    incremental grid: same float math -> bit-identical partitions.
    """
    side = group_side(d_cut, points.shape[-1])
    return jnp.floor(points.astype(jnp.float32) / side).astype(jnp.int64)


def build_grid(points: jnp.ndarray, d_cut: float, g: int | None = None) -> Grid:
    """Build the two-level sorted cell list.  Host-level (measures capacities)."""
    points = jnp.asarray(points, jnp.float32)
    n, d = points.shape
    if g is None:
        g = min(d, 3)
    q = max(int(math.ceil(math.sqrt(d))), 1)     # coarsening factor

    # canonical quantization, then shift to non-negative for key packing (an
    # integer shift: the partition itself stays origin-independent)
    gcoords = canonical_group_coords(points, d_cut)                    # (n, d)
    gcoords = gcoords - jnp.min(gcoords, axis=0)
    ccoords = gcoords[:, :g] // q                                      # (n, g)

    # mixed-radix encode; extents from data (dynamic values, static shapes)
    c_ext = jnp.max(ccoords, axis=0) + 1                               # (g,)
    g_ext = jnp.max(gcoords, axis=0) + 1                               # (d,)
    c_strides = jnp.flip(jnp.cumprod(jnp.flip(jnp.concatenate([c_ext[1:], jnp.ones((1,), jnp.int64)]))))
    g_strides = jnp.flip(jnp.cumprod(jnp.flip(jnp.concatenate([g_ext[1:], jnp.ones((1,), jnp.int64)]))))
    cand_key = (ccoords * c_strides).sum(-1)
    group_key = (gcoords * g_strides).sum(-1)

    # one sort makes candidate cells contiguous and grouping cells contiguous
    # within them (cand key is coarser on the leading dims).
    sort_key = cand_key * (jnp.max(group_key) + 1) + group_key
    order = jnp.argsort(sort_key)
    inv_order = jnp.argsort(order)

    pts_s = points[order]
    cand_s = cand_key[order]
    group_s = group_key[order]
    ccoords_s = ccoords[order].astype(jnp.int32)

    # unique candidate cells, padded to n
    is_first = jnp.concatenate([jnp.ones((1,), bool), cand_s[1:] != cand_s[:-1]])
    num_cells = int(jnp.sum(is_first))
    first_slots = jnp.nonzero(is_first, size=n, fill_value=n - 1)[0].astype(jnp.int32)
    cell_keys = jnp.where(jnp.arange(n) < num_cells, cand_s[first_slots], SENTINEL)
    cell_start = jnp.where(jnp.arange(n) < num_cells, first_slots, n).astype(jnp.int32)
    nxt = jnp.concatenate([cell_start[1:], jnp.full((1,), n, jnp.int32)])
    cell_count = jnp.where(jnp.arange(n) < num_cells, nxt - cell_start, 0).astype(jnp.int32)
    point_cell = (jnp.cumsum(is_first) - 1).astype(jnp.int32)

    # measured capacities (host sync — cell-list build is a host-level op)
    cell_cap = int(jnp.max(cell_count))
    # span = 3 consecutive last-dim cells sharing a prefix offset: bounded by the
    # occupancy of 3 adjacent cells; measure exactly via searchsorted per offset.
    offs = prefix_offsets(g)
    starts, ends = _span_bounds(
        ccoords_s[first_slots[:num_cells].astype(jnp.int32)] if num_cells < n else ccoords_s[first_slots],
        jnp.asarray(offs), c_ext, c_strides, cand_s, g)
    span_cap = int(jnp.max(ends - starts)) if num_cells > 0 else 0

    return Grid(points=pts_s, order=order, inv_order=inv_order,
                cand_key=cand_s, group_key=group_s, cand_coords=ccoords_s,
                cand_extent=c_ext, cand_strides=c_strides,
                cell_keys=cell_keys, cell_start=cell_start, cell_count=cell_count,
                point_cell=point_cell, num_cells=num_cells,
                span_cap=max(span_cap, 1), cell_cap=max(cell_cap, 1),
                g=g, d=d, d_cut=float(d_cut))


def _span_bounds(coords, offs, extent, strides, cand_sorted, g):
    """[start, end) sorted-slot bounds of each (cell, prefix-offset) span.

    coords: (m, g) candidate coords of the query cells; offs: (S, g-1).
    Returns (m, S) int32 starts and ends.  Out-of-range prefix offsets yield
    empty spans.  The span covers last-dim coords {c-1, c, c+1} clamped.
    """
    m = coords.shape[0]
    S = offs.shape[0]
    c = coords.astype(jnp.int64)[:, None, :]                        # (m,1,g)
    if g > 1:
        pref = c[..., :-1] + offs[None, :, :]                       # (m,S,g-1)
        valid = jnp.all((pref >= 0) & (pref < extent[:-1]), axis=-1)
    else:
        pref = jnp.zeros((m, S, 0), jnp.int64)
        valid = jnp.ones((m, S), bool)
    last = c[..., -1]                                               # (m,1)
    lo_last = jnp.maximum(last - 1, 0)
    hi_last = jnp.minimum(last + 1, extent[-1] - 1)
    base = (pref * strides[:-1]).sum(-1) if g > 1 else jnp.zeros((m, S), jnp.int64)
    key_lo = base + lo_last * strides[-1]
    key_hi = base + hi_last * strides[-1]
    starts = jnp.searchsorted(cand_sorted, key_lo, side="left")
    ends = jnp.searchsorted(cand_sorted, key_hi, side="right")
    starts = jnp.where(valid, starts, 0).astype(jnp.int32)
    ends = jnp.where(valid, ends, 0).astype(jnp.int32)
    ends = jnp.maximum(ends, starts)
    return starts, ends


def point_span_bounds(grid: Grid) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per sorted-point candidate spans: (n, S) starts and ends."""
    offs = jnp.asarray(prefix_offsets(grid.g))
    return _span_bounds(grid.cand_coords, offs, grid.cand_extent,
                        grid.cand_strides, grid.cand_key, grid.g)


def cell_span_bounds(grid: Grid) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per unique-cell candidate spans: (n, S) starts/ends (padded cells empty)."""
    first = jnp.minimum(grid.cell_start, grid.points.shape[0] - 1).astype(jnp.int32)
    coords = grid.cand_coords[first]
    offs = jnp.asarray(prefix_offsets(grid.g))
    starts, ends = _span_bounds(coords, offs, grid.cand_extent,
                                grid.cand_strides, grid.cand_key, grid.g)
    alive = (jnp.arange(grid.cell_keys.shape[0]) < grid.num_cells)[:, None]
    return jnp.where(alive, starts, 0), jnp.where(alive, ends, 0)


def unsort_dpc(grid: Grid, rho, rho_key, delta, parent):
    """Map engine outputs computed on ``grid.points`` (sorted layout) back
    to the original point order: per-row fields reindex through
    ``inv_order``; parents translate from sorted-slot to original ids.

    The block-sparse drivers run the fused engine on the grid-sorted table
    (compact tile AABBs) and hand results back through this one helper.
    """
    parent_orig = jnp.where(parent >= 0,
                            grid.order[jnp.maximum(parent, 0)], -1)
    return (rho[grid.inv_order], rho_key[grid.inv_order],
            delta[grid.inv_order],
            parent_orig[grid.inv_order].astype(jnp.int32))


def gather_window(arr: jnp.ndarray, start: jnp.ndarray, length: int):
    """Gather ``arr[start : start+length]`` rows with clamping; returns (length, ...)."""
    idx = start + jnp.arange(length)
    idx_c = jnp.minimum(idx, arr.shape[0] - 1)
    return arr[idx_c], idx


def sq_dists(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Squared Euclidean distances (|A|, |B|) in the MXU-friendly expanded form."""
    a2 = jnp.sum(a * a, axis=-1)[:, None]
    b2 = jnp.sum(b * b, axis=-1)[None, :]
    ab = a @ b.T
    return jnp.maximum(a2 + b2 - 2.0 * ab, 0.0)
