"""Parameter selection helpers (d_cut from the paper's quantile rule)."""
from __future__ import annotations

import numpy as np


def pick_dcut(points: np.ndarray, target_rho: float = 30.0,
              sample: int = 512, seed: int = 0) -> float:
    """d_cut such that the average local density is ~target_rho.

    rho(d) ~ n * F(d) with F the pairwise-distance CDF; pick the distance
    quantile q = target_rho / n from a sampled distance matrix — the
    standard 1-2% rule the DPC paper applies to its datasets.
    """
    points = np.asarray(points)
    n = len(points)
    rng = np.random.default_rng(seed)
    idx = rng.choice(n, size=min(sample, n), replace=False)
    sub = points[idx].astype(np.float64)
    d2 = ((sub[:, None, :] - sub[None, :, :]) ** 2).sum(-1)
    d = np.sqrt(d2[np.triu_indices(len(sub), 1)])
    q = min(max(target_rho / n, 1e-4), 0.5)
    return float(np.quantile(d, q))
