"""CFSFDP-A baseline [Bai et al., Pattern Recognition'17] — the paper's
state-of-the-art exact competitor (§2.2, §6).

k-means pivots + triangle inequality filter candidate sets for the rho range
count; per the paper's own experimental setup, the dependent distances use the
Scan approach (Table 1 notes CFSFDP-A's own delta step is Omega(n^2) and
slower than Scan's).

TPU adaptation: the per-cluster triangle-inequality test
|dist(p, pivot_c)| - r_c >= d_cut  (skip cluster c entirely for p) becomes an
(n x k) mask; surviving (point, cluster) pairs are evaluated over padded
per-cluster windows.  k-means's noise sensitivity (weak filtering) is exactly
what the paper criticizes — reproduced by benchmarks/decomposed.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .dpc_types import DPCResult, with_jitter
from .grid import sq_dists
from .scan import dependent_scan


@partial(jax.jit, static_argnames=("k", "iters"))
def kmeans_pivots(points, k: int, iters: int = 10, seed: int = 0):
    n, d = points.shape
    key = jax.random.PRNGKey(seed)
    init = points[jax.random.choice(key, n, (k,), replace=False)]

    def step(cents, _):
        d2 = sq_dists(points, cents)
        assign = jnp.argmin(d2, axis=1)
        sums = jax.ops.segment_sum(points, assign, num_segments=k)
        cnts = jax.ops.segment_sum(jnp.ones((n,), jnp.float32), assign, num_segments=k)
        cents = jnp.where(cnts[:, None] > 0, sums / jnp.maximum(cnts, 1)[:, None], cents)
        return cents, None

    cents, _ = jax.lax.scan(step, init, None, length=iters)
    assign = jnp.argmin(sq_dists(points, cents), axis=1)
    return cents, assign


def run_cfsfdp_a(points, d_cut: float, *, k: int = 32, block: int = 256,
                 scan_block: int = 1024) -> DPCResult:
    points = jnp.asarray(points, jnp.float32)
    n, d = points.shape
    k = min(k, n)
    cents, assign = kmeans_pivots(points, k)
    # sort by pivot-cluster id -> contiguous windows
    order = jnp.argsort(assign)
    inv = jnp.argsort(order)
    pts_s = points[order]
    as_s = assign[order]
    is_first = jnp.concatenate([jnp.ones((1,), bool), as_s[1:] != as_s[:-1]])
    seg = jnp.cumsum(is_first) - 1
    start_per_pt = jax.ops.segment_min(
        jnp.where(is_first, jnp.arange(n), n), seg, num_segments=k)
    count_per_cluster = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), as_s,
                                            num_segments=k)
    cap = int(jnp.max(count_per_cluster))
    # cluster radii for the triangle-inequality filter
    dist_to_own = jnp.sqrt(jnp.sum((points - cents[assign]) ** 2, -1))
    radius = jax.ops.segment_max(dist_to_own, assign, num_segments=k)

    rho = _density(points, pts_s, cents, radius, start_per_pt,
                   count_per_cluster, d_cut, cap, block)
    rho_key = with_jitter(rho)
    delta, parent = dependent_scan(points, rho_key, block=scan_block)
    return DPCResult(rho=rho, rho_key=rho_key, delta=delta, parent=parent)


@partial(jax.jit, static_argnames=("cap", "block"))
def _density(points, pts_s, cents, radius, start, count, d_cut, cap: int, block: int):
    n, d = points.shape
    k = cents.shape[0]
    d2cut = jnp.float32(d_cut) ** 2
    nb = -(-n // block)
    npad = nb * block
    pts_p = jnp.pad(points, ((0, npad - n), (0, 0)))

    def chunk(i0):
        rows = jax.lax.dynamic_slice_in_dim(pts_p, i0, block, 0)   # (B, d)
        dp = jnp.sqrt(sq_dists(rows, cents))                       # (B, k)
        keep = dp - radius[None, :] < d_cut                        # triangle filter
        # evaluate every unpruned cluster window
        def per_cluster(c, acc):
            idx = start[c] + jnp.arange(cap)
            valid = jnp.arange(cap) < count[c]
            cand = pts_s[jnp.minimum(idx, n - 1)]
            d2 = jnp.sum((rows[:, None, :] - cand[None, :, :]) ** 2, -1)
            cnt = jnp.sum((d2 < d2cut) & valid[None, :], axis=1).astype(jnp.int32)
            return acc + jnp.where(keep[:, c], cnt, 0)

        cnt = jax.lax.fori_loop(0, k, per_cluster, jnp.zeros((block,), jnp.int32))
        return cnt

    cnt = jax.lax.map(chunk, jnp.arange(nb) * block)
    return cnt.reshape(-1)[:n].astype(jnp.float32)
