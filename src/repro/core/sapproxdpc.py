"""S-Approx-DPC (§5): grid sampling + cell-based clustering.

A coarse grid G' with side eps*d_cut/sqrt(d) picks one *representative* per
cell; only representatives do range searches (exact rho) and dependent-point
searches; the remaining points chain to their representative in O(1).  Point
clustering becomes cell clustering — range-search count drops from n to |G'|.

Phase 1 (paper): a denser representative within (1+eps)*d_cut can be taken as
the approximate dependent (we use the d_cut stencil, a subset of that bound,
so the paper's (1+eps)*d_cut delta bound holds a fortiori).
Phase 2: unresolved representatives get their exact nearest denser
representative.  The paper prunes with temporal clusters + triangle
inequality (a CPU work-saving trick); the TPU form is one blocked masked-NN
over the (small) unresolved set — same output, dense schedule (DESIGN.md §2).

Members: parent = representative, delta = min(eps,1)*d_cut (< delta_min, so
members are never centers — matching "rho_min/centers are not applicable to
non-picked points"), rho = representative's rho.
"""
from __future__ import annotations

import math

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.engine.planner import as_plan

from .dpc_types import DPCResult, density_jitter, with_jitter
from .exdpc import _pow2_pad
from .grid import build_grid, Grid
from .stencil import density_for_slots, dependent_stencil_slots


def coarse_cell_key(points: jnp.ndarray, d_cut: float, eps: float) -> jnp.ndarray:
    n, d = points.shape
    side = eps * d_cut / math.sqrt(d)
    lo = jnp.min(points, axis=0)
    coords = jnp.floor((points - lo) / side).astype(jnp.int64)
    ext = jnp.max(coords, axis=0) + 1
    strides = jnp.flip(jnp.cumprod(jnp.flip(jnp.concatenate([ext[1:], jnp.ones((1,), jnp.int64)]))))
    return (coords * strides).sum(-1)


def run_sapproxdpc(points, d_cut: float, eps: float = 0.8, *,
                   g: int | None = None, fallback_block: int = 4096,
                   grid: Grid | None = None, exec_spec=None) -> DPCResult:
    if eps <= 0.0:
        raise ValueError(f"S-Approx-DPC needs eps > 0 (the coarse-grid "
                         f"side is eps*d_cut/sqrt(d)); got {eps!r}")
    points = jnp.asarray(points, jnp.float32)
    pl = as_plan(exec_spec, points)
    n = points.shape[0]
    block = pl.block or 256     # stencil row-tile default (jnp path)
    use_engine = pl.backend.mxu_dense or pl.sparse
    if grid is None:
        with obs.span("sapproxdpc.grid", n=n) as sp:
            grid = sp.sync(build_grid(points, d_cut, g=g))

    # --- representatives: first point of each coarse cell in grid-sorted order
    with obs.span("sapproxdpc.reps", n=n) as sp:
        ckey_sorted = coarse_cell_key(grid.points, d_cut, eps)
        order_c = jnp.argsort(ckey_sorted, stable=True)
        ck = ckey_sorted[order_c]
        is_first = jnp.concatenate([jnp.ones((1,), bool), ck[1:] != ck[:-1]])
        seg = (jnp.cumsum(is_first) - 1).astype(jnp.int32)  # coarse segment ids
        num_reps = int(jnp.sum(is_first))
        # rep slot (grid-sorted index) per coarse segment
        rep_slot_per_seg = jax.ops.segment_min(
            jnp.where(is_first, order_c, n).astype(jnp.int32), seg,
            num_segments=n)
        rep_slots = np.asarray(rep_slot_per_seg[:num_reps])
        sp.set(num_reps=num_reps)
    m_pad = _pow2_pad(max(num_reps, 1))
    rep_slots_p = jnp.asarray(np.pad(rep_slots, (0, m_pad - num_reps),
                                     constant_values=n))

    # --- exact rho for representatives only ---
    if use_engine:
        # fused engine sweep: reps x all-points range count AND the NN among
        # the strictly-denser *representative* columns (nn_sel gates the
        # kept-k to rep rows), one pass — phases 1+2 fall out of its result.
        # the density jitter indexes by *original* point id, so rep queries
        # carry jitter[order[slot]] — identical keys to rk_sorted[rep_slots]
        # (rep slots ascend in grid-sorted order, so the block-sparse layout
        # sees compact query tiles with no extra sort)
        rep_jit = density_jitter(n)[grid.order[jnp.asarray(rep_slots)]]
        with obs.span("sapproxdpc.rep_sweep", n=n, reps=num_reps,
                      layout=pl.layout) as sp:
            rep_rho, _, nn_d, nn_p = sp.sync(pl.rho_delta(
                grid.points[jnp.asarray(rep_slots)], grid.points, d_cut,
                jitter=rep_jit, y_sel_slots=jnp.asarray(rep_slots)))
    else:
        with obs.span("sapproxdpc.rep_rho", n=n, reps=num_reps) as sp:
            rep_rho = sp.sync(density_for_slots(grid, rep_slots_p,
                                                block=block)[:num_reps])

    # rho per point: members inherit their representative's rho
    rho_sorted = jnp.zeros((n,), jnp.float32)
    seg_of_sorted = jnp.zeros((n,), jnp.int32).at[order_c].set(seg)
    rep_rho_per_seg = jnp.zeros((n,), jnp.float32).at[
        jnp.arange(num_reps)].set(rep_rho)
    rho_sorted = rep_rho_per_seg[seg_of_sorted]
    rho = rho_sorted[grid.inv_order]
    rho_key = with_jitter(rho)
    rk_sorted = rho_key[grid.order]

    rep_mask_sorted = jnp.zeros((n,), bool).at[jnp.minimum(rep_slots_p, n - 1)].set(
        rep_slots_p < n)
    rep_pts = grid.points[jnp.asarray(rep_slots)]
    rep_rk = rk_sorted[jnp.asarray(rep_slots)]
    if use_engine:
        # --- phases 1+2 straight from the fused sweep above: NN within
        #     d_cut -> phase-1 resolution (delta stamped d_cut, the
        #     tighter-than-paper bound below); otherwise the NN already IS
        #     the phase-2 exact answer.  nn_p is in sorted-slot space (the
        #     candidate columns were the full table, gated to rep rows).
        nn_d = np.asarray(nn_d)
        nn_p = np.asarray(nn_p)
        found = np.isfinite(nn_d) & (nn_d < d_cut)
        p2_delta = np.where(found, np.float32(d_cut),
                            np.where(np.isfinite(nn_d), nn_d, np.inf))
        p2_parent = nn_p
    else:
        with obs.span("sapproxdpc.phase12", reps=num_reps) as sp:
            # --- phase 1: stencil among representatives (d_cut ⊂
            #     (1+eps)d_cut bound) ---
            rk_reps_only = jnp.where(rep_mask_sorted, rk_sorted, -jnp.inf)
            p1_delta, p1_parent, p1_found = dependent_stencil_slots(
                grid, rk_reps_only, rep_slots_p, block=block)
            # The paper's phase-1 search radius is (1+eps)*d_cut and stamps
            # that bound as the delta.  Our stencil only resolves within
            # d_cut, so d_cut is the valid *and tighter* bound — resolved
            # reps can never become spurious centers at large eps
            # (beyond-paper improvement, DESIGN.md §9).
            p1_delta = jnp.where(p1_found, jnp.float32(d_cut), jnp.inf)

            # --- phase 2: exact NN among representatives for unresolved
            #     reps ---
            found_np = np.asarray(p1_found[:num_reps])
            unresolved = np.nonzero(~found_np)[0]
            p2_delta = np.asarray(p1_delta[:num_reps]).copy()
            p2_parent = np.asarray(p1_parent[:num_reps]).copy()  # sorted slots
            if unresolved.size:
                mq = _pow2_pad(unresolved.size)
                qs = np.pad(unresolved, (0, mq - unresolved.size))
                fd, fp = pl.denser_nn(rep_pts[qs], rep_rk[qs], rep_pts,
                                      rep_rk, block=fallback_block,
                                      layout=None)
                fd = np.asarray(fd)[: unresolved.size]
                fp = np.asarray(fp)[: unresolved.size]    # rep-index space
                p2_delta[unresolved] = np.where(np.isfinite(fd), fd, np.inf)
                p2_parent[unresolved] = np.where(
                    fp >= 0, rep_slots[np.maximum(fp, 0)], -1)
            sp.set(unresolved=int(unresolved.size))

    # --- assemble per-point delta/parent in sorted space ---
    with obs.span("sapproxdpc.assemble", n=n) as sp:
        rep_parent_per_seg = jnp.full((n,), -1, jnp.int32).at[
            jnp.arange(num_reps)].set(jnp.asarray(p2_parent))
        rep_delta_per_seg = jnp.full((n,), jnp.inf).at[
            jnp.arange(num_reps)].set(jnp.asarray(p2_delta))
        rep_slot_of_seg = jnp.full((n,), -1, jnp.int32).at[
            jnp.arange(num_reps)].set(jnp.asarray(rep_slots))

        member_delta = jnp.float32(min(eps, 1.0) * d_cut)
        is_rep_sorted = rep_mask_sorted
        parent_s = jnp.where(is_rep_sorted, rep_parent_per_seg[seg_of_sorted],
                             rep_slot_of_seg[seg_of_sorted])
        delta_s = jnp.where(is_rep_sorted, rep_delta_per_seg[seg_of_sorted],
                            member_delta)

        delta = delta_s[grid.inv_order]
        parent_sorted = parent_s[grid.inv_order]
        parent = jnp.where(parent_sorted >= 0, grid.order[parent_sorted],
                           -1).astype(jnp.int32)
        sp.sync((delta, parent))
    return DPCResult(rho=rho, rho_key=rho_key, delta=delta, parent=parent)
